//! Serving-subsystem integration: epoch snapshots vs the offline pipeline.
//!
//! The contract under test (ISSUE 4 acceptance): a serve session that
//! ingests a stream in shards, refreshes an epoch snapshot mid-stream, and
//! answers queries produces factors **bitwise identical** to the offline
//! `Pipeline::run` on the same entry prefix — at 1, 2 and 8 ingest workers,
//! with queries running concurrently and never observing a torn snapshot.
//! Run under the CI thread-matrix job (`SMPPCA_THREADS=1/4`) as well.

use smppca::algo::SmpPcaConfig;
use smppca::coordinator::{Pipeline, PipelineConfig};
use smppca::linalg::Mat;
use smppca::rng::Pcg64;
use smppca::server::{ServeProtocol, Snapshot, StreamSession, StreamSpec};
use smppca::stream::{Entry, EntrySource, ShuffledMatrixSource, StreamMeta, VecSource};

const D: usize = 40;
const N1: usize = 14;
const N2: usize = 12;

fn algo() -> SmpPcaConfig {
    SmpPcaConfig {
        rank: 3,
        sketch_size: 24,
        samples: 500.0,
        iters: 5,
        seed: 5,
        ..Default::default()
    }
}

fn meta() -> StreamMeta {
    StreamMeta { d: D, n1: N1, n2: N2 }
}

fn spec(workers: usize) -> StreamSpec {
    StreamSpec { meta: meta(), algo: algo(), workers, channel_capacity: 16 }
}

/// The full entry stream, in a fixed arbitrary (shuffled) order.
fn stream_entries() -> Vec<Entry> {
    let mut rng = Pcg64::new(42);
    let a = Mat::gaussian(D, N1, &mut rng);
    let b = Mat::gaussian(D, N2, &mut rng);
    let mut out = Vec::new();
    let _ = Box::new(ShuffledMatrixSource { a, b, seed: 77 }).for_each(&mut |e| {
        out.push(e);
        std::ops::ControlFlow::Continue(())
    });
    out
}

/// Offline reference: the batch pipeline on an entry prefix.
fn offline_factors(entries: &[Entry]) -> (Mat, Mat, usize) {
    let cfg = PipelineConfig { algo: algo(), workers: 2, channel_capacity: 64 };
    let out = Pipeline::new(cfg)
        .run(Box::new(VecSource { meta: meta(), entries: entries.to_vec() }))
        .unwrap();
    (out.result.factors.u, out.result.factors.v, out.result.samples_drawn)
}

#[test]
fn mid_stream_snapshot_bitwise_matches_offline_pipeline_at_1_2_8_workers() {
    let entries = stream_entries();
    let split = entries.len() * 3 / 5;
    let (u_prefix, v_prefix, m_prefix) = offline_factors(&entries[..split]);
    let (u_full, v_full, m_full) = offline_factors(&entries);
    for workers in [1usize, 2, 8] {
        let s = StreamSession::open("bw", spec(workers)).unwrap();
        // odd chunk size so batch boundaries never align with anything
        for chunk in entries[..split].chunks(7) {
            s.ingest(chunk).unwrap();
        }
        let snap1 = s.refresh().unwrap();
        assert_eq!(snap1.epoch, 1);
        assert_eq!(snap1.entries_ingested, split as u64);
        assert_eq!(snap1.samples_drawn, m_prefix, "workers={workers}");
        assert_eq!(snap1.factors.u.data(), u_prefix.data(), "workers={workers} (U, mid)");
        assert_eq!(snap1.factors.v.data(), v_prefix.data(), "workers={workers} (V, mid)");
        // keep streaming past the snapshot, then take the next epoch
        for chunk in entries[split..].chunks(11) {
            s.ingest(chunk).unwrap();
        }
        let snap2 = s.refresh().unwrap();
        assert_eq!(snap2.epoch, 2);
        assert_eq!(snap2.samples_drawn, m_full, "workers={workers}");
        assert_eq!(snap2.factors.u.data(), u_full.data(), "workers={workers} (U, full)");
        assert_eq!(snap2.factors.v.data(), v_full.data(), "workers={workers} (V, full)");
        // the published snapshot advanced; epoch-1 readers keep their Arc
        assert_eq!(s.snapshot().unwrap().epoch, 2);
        assert_eq!(snap1.epoch, 1);
        s.close().unwrap();
    }
}

#[test]
fn concurrent_queries_never_observe_torn_snapshots() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    let entries = stream_entries();
    let s = StreamSession::open("torn", spec(2)).unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let mut readers = Vec::new();
    for r in 0..4 {
        let session = Arc::clone(&s);
        let stop_ref = Arc::clone(&stop);
        readers.push(smppca::runtime::spawn_thread(&format!("serve-reader-{r}"), move || {
            let mut last_epoch = 0u64;
            let mut observed = 0u64;
            while !stop_ref.load(Ordering::Relaxed) {
                if let Some(snap) = session.snapshot() {
                    assert!(snap.verify_integrity(), "torn snapshot observed");
                    assert!(
                        snap.epoch >= last_epoch,
                        "epoch went backwards: {} after {last_epoch}",
                        snap.epoch
                    );
                    last_epoch = snap.epoch;
                    let v = snap.estimate_entry(0, 0).unwrap();
                    assert!(v.is_finite());
                    observed += 1;
                }
                std::thread::yield_now();
            }
            observed
        }));
    }
    // writer: interleave ingest batches with refreshes
    for (i, chunk) in entries.chunks(37).enumerate() {
        s.ingest(chunk).unwrap();
        if i % 2 == 0 {
            s.refresh().unwrap();
        }
    }
    s.refresh().unwrap();
    stop.store(true, Ordering::Relaxed);
    let total: u64 = readers.into_iter().map(|r| r.join().unwrap()).sum();
    assert!(total > 0, "readers never saw a snapshot");
    assert!(s.snapshot().unwrap().epoch >= 1);
    s.close().unwrap();
}

#[test]
fn top_component_scales_cached_at_publish_bitwise_match_factors() {
    // Query-side caching: `top_components` now serves scales precomputed at
    // snapshot publish time. Pin them bitwise against the historical
    // per-call computation (‖U_t‖·‖V_t‖ from the published factors), on the
    // live snapshot and across a save/load round trip.
    let entries = stream_entries();
    let s = StreamSession::open("topcache", spec(2)).unwrap();
    s.ingest(&entries).unwrap();
    let snap = s.refresh().unwrap();
    let want: Vec<f64> = (0..snap.rank)
        .map(|t| snap.factors.u.col_norm(t) * snap.factors.v.col_norm(t))
        .collect();
    assert_eq!(snap.top_components(snap.rank), want, "cached scales diverged from factors");
    assert_eq!(snap.top_components(2), want[..2].to_vec(), "prefix query must slice the same cache");
    assert_eq!(snap.top_components(100).len(), snap.rank, "r clamps to the factor rank");
    let path = std::env::temp_dir().join(format!("smppca_top_cache_{}.bin", std::process::id()));
    snap.save(&path).unwrap();
    let loaded = Snapshot::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.top_components(snap.rank), want, "reloaded cache diverged");
    s.close().unwrap();
}

#[test]
fn checkpointed_session_resumes_bitwise() {
    let entries = stream_entries();
    let split = entries.len() / 2;
    let (u_full, v_full, _) = offline_factors(&entries);
    let dir = std::env::temp_dir().join(format!("smppca_serve_ckpt_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    // first life: ingest half, checkpoint shard states, die
    {
        let s = StreamSession::open("life1", spec(3)).unwrap();
        for chunk in entries[..split].chunks(9) {
            s.ingest(chunk).unwrap();
        }
        assert_eq!(s.checkpoint(&dir).unwrap(), s.workers());
        s.close().unwrap();
    }
    // second life: restore (worker count pinned by the checkpoint), finish
    // the stream, refresh — bitwise the uninterrupted offline run
    let states = StreamSession::restore_states(&dir).unwrap();
    assert_eq!(states.len(), 3);
    let s = StreamSession::open_with_states("life2", spec(3), states).unwrap();
    for chunk in entries[split..].chunks(13) {
        s.ingest(chunk).unwrap();
    }
    let snap = s.refresh().unwrap();
    assert_eq!(snap.factors.u.data(), u_full.data());
    assert_eq!(snap.factors.v.data(), v_full.data());
    s.close().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn snapshot_persistence_recovers_into_a_fresh_session() {
    let entries = stream_entries();
    let path = std::env::temp_dir().join(format!("smppca_serve_snap_{}.bin", std::process::id()));
    let saved = {
        let s = StreamSession::open("persist", spec(2)).unwrap();
        s.ingest(&entries).unwrap();
        let snap = s.refresh().unwrap();
        snap.save(&path).unwrap();
        s.close().unwrap();
        snap
    };
    let loaded = Snapshot::load(&path).unwrap();
    assert_eq!(loaded.epoch, saved.epoch);
    assert_eq!(loaded.factors.u.data(), saved.factors.u.data());
    assert_eq!(loaded.factors.v.data(), saved.factors.v.data());
    // recovery: fresh session serves queries from the restored snapshot
    // before re-ingesting anything, and its next refresh epoch advances
    // past the restored one
    let s = StreamSession::open("recovered", spec(2)).unwrap();
    s.install_snapshot(loaded).unwrap();
    let snap = s.snapshot().unwrap();
    assert_eq!(snap.epoch, saved.epoch);
    assert_eq!(snap.estimate_entry(1, 2).unwrap(), saved.estimate_entry(1, 2).unwrap());
    s.ingest(&entries).unwrap();
    let next = s.refresh().unwrap();
    assert!(next.epoch > saved.epoch, "epochs must stay monotone across recovery");
    s.close().unwrap();
    std::fs::remove_file(&path).ok();
    // spec mismatch is refused
    let other = StreamSession::open(
        "otherspec",
        StreamSpec {
            algo: SmpPcaConfig { seed: 999, ..algo() },
            ..spec(1)
        },
    )
    .unwrap();
    assert!(other.install_snapshot(saved).is_err());
    other.close().unwrap();
}

#[test]
fn protocol_serve_session_matches_offline_pipeline_bitwise() {
    // Drive the whole thing through the line protocol (what `smppca serve`
    // speaks): ingest in shards, refresh mid-stream, query — the printed
    // estimate at (i, j) must equal the offline pipeline's factor product
    // exactly (17-significant-digit prints round-trip f64).
    let entries = stream_entries();
    let split = entries.len() * 3 / 5;
    let (u_prefix, v_prefix, _) = offline_factors(&entries[..split]);
    let p = ServeProtocol::new();
    let a = algo();
    let r = p.handle(&format!(
        "open s d={D} n1={N1} n2={N2} k={} rank={} seed={} samples={} iters={} workers=2",
        a.sketch_size, a.rank, a.seed, a.samples, a.iters
    ));
    assert!(r.starts_with("ok open s "), "{r}");
    for chunk in entries[..split].chunks(25) {
        let records: Vec<String> = chunk
            .iter()
            .map(|e| {
                let m = match e.matrix {
                    smppca::stream::MatrixId::A => "A",
                    smppca::stream::MatrixId::B => "B",
                };
                format!("{m}:{}:{}:{:.17e}", e.row, e.col, e.value)
            })
            .collect();
        let resp = p.handle(&format!("ingest s {}", records.join(" ")));
        assert!(resp.starts_with("ok ingest s "), "{resp}");
    }
    let r = p.handle("refresh s");
    assert!(r.starts_with("ok refresh s epoch=1 "), "{r}");
    for i in [0usize, 3, N1 - 1] {
        for j in [0usize, 5, N2 - 1] {
            let resp = p.handle(&format!("estimate s {i} {j}"));
            let value: f64 = resp
                .rsplit("value=")
                .next()
                .unwrap()
                .trim()
                .parse()
                .unwrap_or_else(|_| panic!("unparsable response '{resp}'"));
            let expect: f64 =
                (0..a.rank).map(|t| u_prefix[(i, t)] * v_prefix[(j, t)]).sum();
            assert_eq!(value, expect, "({i}, {j}): protocol vs offline factors");
        }
    }
    let r = p.handle("top s");
    assert!(r.starts_with("top s epoch=1 r=3 scales="), "{r}");
    let r = p.handle("stats s");
    assert!(r.contains("epoch=1"), "{r}");
    assert!(r.contains("serve/refresh"), "stats must carry the stage metrics: {r}");
    assert_eq!(p.handle("close s"), "ok close s");
}
