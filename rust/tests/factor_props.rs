//! Property suite for the blocked factorization subsystem (ISSUE 3):
//!
//! * blocked compact-WY QR replays the unblocked Householder oracle
//!   (`qr_thin`) to 1e-10 on ragged random shapes, with `QᵀQ − I`
//!   orthogonality bounds;
//! * TSQR matches the oracle up to column signs and is **bitwise**
//!   invariant to the worker count at 1/2/8;
//! * the shape-aware `factor::svd` matches the `svd_jacobi` oracle
//!   (singular values + reconstruction ≤ 1e-10) on ragged shapes, and is
//!   bit-identical on the near-square Jacobi dispatch;
//! * the randomized `factor::rsvd_op` is bitwise thread-invariant;
//! * end to end: the migrated WAltMin / `smp_pca` / streaming pipeline
//!   produce **bitwise identical** output at 1/2/8 leader threads on the
//!   seeded reference problem.
//!
//! Run under `SMPPCA_THREADS=1` and `=4` by the CI thread-matrix job.

use smppca::algo::{smp_pca, SmpPcaConfig};
use smppca::completion::waltmin::{waltmin, Observation, WAltMinConfig};
use smppca::coordinator::{Pipeline, PipelineConfig};
use smppca::linalg::factor;
use smppca::linalg::{fro_norm, qr_thin, svd_jacobi, Mat, QrThin};
use smppca::rng::Pcg64;
use smppca::stream::ShuffledMatrixSource;
use smppca::testing::{assert_close, canonicalize_qr, prop};

fn orthogonality_defect(q: &Mat) -> f64 {
    let qtq = q.t_matmul(q);
    let mut worst = 0.0f64;
    for i in 0..qtq.rows() {
        for j in 0..qtq.cols() {
            let expect = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((qtq[(i, j)] - expect).abs());
        }
    }
    worst
}

#[test]
fn blocked_qr_matches_oracle_on_ragged_shapes() {
    prop(301, 25, |rng| {
        // m ≥ n + 3: comfortably conditioned draws, so the blocked and
        // unblocked computation orders agree well inside the 1e-10 bound.
        let n = 1 + rng.next_below(14) as usize;
        let m = n + 3 + rng.next_below(60) as usize;
        let a = Mat::gaussian(m, n, rng);
        let blocked = factor::qr_blocked(&a, factor::NB, 0);
        let oracle = qr_thin(&a);
        assert_close(blocked.r.data(), oracle.r.data(), 1e-10);
        assert_close(blocked.q.data(), oracle.q.data(), 1e-10);
        assert!(orthogonality_defect(&blocked.q) < 1e-10, "QᵀQ − I too large");
    });
}

#[test]
fn shape_aware_qr_contract_and_orthogonality() {
    // The driver (blocked or TSQR, chosen by shape) always satisfies
    // QR = A, ‖QᵀQ − I‖_max ≤ 1e-10, R upper-triangular.
    prop(302, 15, |rng| {
        let n = 1 + rng.next_below(8) as usize;
        let m = n + rng.next_below(900) as usize; // spans both dispatch arms
        let a = Mat::gaussian(m, n, rng);
        let QrThin { q, r } = factor::qr(&a, 0);
        assert_close(q.matmul(&r).data(), a.data(), 1e-10);
        assert!(orthogonality_defect(&q) < 1e-10);
        for i in 0..n {
            for j in 0..i {
                assert!(r[(i, j)].abs() < 1e-12);
            }
        }
    });
}

#[test]
fn tsqr_matches_oracle_and_is_thread_invariant_1_2_8() {
    let mut rng = Pcg64::new(303);
    for &(m, n) in &[(800usize, 6usize), (1536, 12), (2500, 3)] {
        let a = Mat::gaussian(m, n, &mut rng);
        let f1 = factor::tsqr(&a, 1);
        // Oracle agreement (up to column signs).
        let (qt, rt) = canonicalize_qr(&f1);
        let (qo, ro) = canonicalize_qr(&qr_thin(&a));
        assert_close(rt.data(), ro.data(), 1e-10);
        assert_close(qt.data(), qo.data(), 1e-10);
        // Bitwise identical at 2 and 8 workers.
        for t in [2usize, 8] {
            let ft = factor::tsqr(&a, t);
            assert_eq!(ft.q.data(), f1.q.data(), "{m}x{n} workers={t}");
            assert_eq!(ft.r.data(), f1.r.data(), "{m}x{n} workers={t}");
        }
    }
}

#[test]
fn factor_svd_matches_jacobi_oracle_on_ragged_shapes() {
    prop(304, 15, |rng| {
        let m = 2 + rng.next_below(40) as usize;
        let n = 2 + rng.next_below(14) as usize;
        let a = Mat::gaussian(m, n, rng);
        let fast = factor::svd(&a, 0);
        let oracle = svd_jacobi(&a);
        assert_close(&fast.s, &oracle.s, 1e-10);
        let diff = fast.reconstruct().sub(&a);
        assert!(
            fro_norm(&diff) <= 1e-10 * fro_norm(&a).max(1.0),
            "reconstruction defect {}",
            fro_norm(&diff)
        );
        // U, V orthonormal up to rank.
        for (factor_mat, dim) in [(&fast.u, n), (&fast.v, n)] {
            let g = factor_mat.t_matmul(factor_mat);
            for i in 0..dim {
                if fast.s[i] > 1e-10 * fast.s[0].max(1e-300) {
                    assert!((g[(i, i)] - 1.0).abs() < 1e-9);
                }
            }
        }
    });
}

#[test]
fn rsvd_op_is_thread_invariant_1_2_8() {
    let mut rng = Pcg64::new(305);
    let u = Mat::gaussian(700, 5, &mut rng);
    let v = Mat::gaussian(60, 5, &mut rng);
    let a = u.matmul_t(&v); // 700×60 rank-5
    let run = |threads: usize| {
        factor::rsvd_op(
            &|x, y| a.gemv_into(x, y),
            &|x, y| a.gemv_t_into(x, y),
            700,
            60,
            5,
            7,
            2,
            0xabc,
            threads,
        )
    };
    let s1 = run(1);
    let diff = a.sub(&s1.reconstruct());
    assert!(fro_norm(&diff) < 1e-8 * fro_norm(&a), "rsvd must recover rank-5 exactly");
    for t in [2usize, 8] {
        let st = run(t);
        assert_eq!(st.s, s1.s, "threads={t}");
        assert_eq!(st.u.data(), s1.u.data(), "threads={t}");
        assert_eq!(st.v.data(), s1.v.data(), "threads={t}");
    }
}

#[test]
fn waltmin_bitwise_identical_at_1_2_8_threads() {
    // Big enough that the init SVD goes through TSQR (n1 ≫ r) and the ALS
    // solves cross the parallel grain.
    let n1 = 400;
    let n2 = 40;
    let mut rng = Pcg64::new(306);
    let u = Mat::gaussian(n1, 3, &mut rng);
    let v = Mat::gaussian(n2, 3, &mut rng);
    let m = u.matmul_t(&v);
    let mut obs = Vec::new();
    for i in 0..n1 {
        for j in 0..n2 {
            if (i + 3 * j) % 2 == 0 {
                obs.push(Observation { i, j, value: m[(i, j)], q_hat: 0.5 });
            }
        }
    }
    let base = WAltMinConfig { rank: 3, iters: 3, threads: 1, ..Default::default() };
    let reference = waltmin(&obs, n1, n2, &base);
    for t in [2usize, 8] {
        let cfg = WAltMinConfig { threads: t, ..base.clone() };
        let out = waltmin(&obs, n1, n2, &cfg);
        assert_eq!(out.factors.u.data(), reference.factors.u.data(), "threads={t}");
        assert_eq!(out.factors.v.data(), reference.factors.v.data(), "threads={t}");
        assert_eq!(out.residual_log, reference.residual_log, "threads={t}");
    }
}

#[test]
fn smp_pca_end_to_end_bitwise_identical_at_1_2_8_threads() {
    // The seeded reference problem of the coordinator tests: the whole
    // migrated leader finish (sampling → estimation → factor-backed
    // WAltMin) must not move a bit when the thread knob changes.
    let mut rng = Pcg64::new(42);
    let (a, b) = smppca::datasets::gd_synthetic(60, 20, 22, &mut rng);
    let base = SmpPcaConfig { rank: 3, sketch_size: 24, seed: 5, iters: 6, threads: 1, ..Default::default() };
    let reference = smp_pca(&a, &b, &base).unwrap();
    for t in [2usize, 8] {
        let cfg = SmpPcaConfig { threads: t, ..base.clone() };
        let out = smp_pca(&a, &b, &cfg).unwrap();
        assert_eq!(out.factors.u.data(), reference.factors.u.data(), "threads={t}");
        assert_eq!(out.factors.v.data(), reference.factors.v.data(), "threads={t}");
        assert_eq!(out.samples_drawn, reference.samples_drawn, "threads={t}");
        assert_eq!(out.residual_log, reference.residual_log, "threads={t}");
    }
}

#[test]
fn pipeline_bitwise_identical_across_leader_threads() {
    // Streaming pipeline on the same reference problem: sketch-pass worker
    // count AND leader thread count both swept; one reference output.
    let mut rng = Pcg64::new(42);
    let (a, b) = smppca::datasets::gd_synthetic(60, 20, 22, &mut rng);
    let run = |threads: usize| {
        let algo = SmpPcaConfig {
            rank: 3,
            sketch_size: 24,
            seed: 5,
            iters: 6,
            threads,
            ..Default::default()
        };
        let cfg = PipelineConfig { algo, workers: 2, channel_capacity: 64 };
        Pipeline::new(cfg)
            .run(Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 9 }))
            .unwrap()
            .result
    };
    let reference = run(1);
    for t in [2usize, 8] {
        let out = run(t);
        assert_eq!(out.factors.u.data(), reference.factors.u.data(), "threads={t}");
        assert_eq!(out.factors.v.data(), reference.factors.v.data(), "threads={t}");
    }
}

#[test]
fn rank_deficient_inputs_stay_finite_through_the_subsystem() {
    // Regression for the degenerate-reflector guard: zero and duplicate
    // columns through blocked QR, TSQR, and the SVD driver.
    let mut rng = Pcg64::new(307);
    let base = Mat::gaussian(600, 1, &mut rng);
    let a = Mat::from_fn(600, 4, |i, j| match j {
        1 => 0.0,
        3 => base[(i, 0)],
        _ => base[(i, 0)] * ((i + j) % 3) as f64,
    });
    for f in [factor::qr_blocked(&a, factor::NB, 0), factor::tsqr(&a, 2), factor::qr(&a, 0)] {
        assert!(f.q.data().iter().all(|v| v.is_finite()));
        assert_close(f.q.matmul(&f.r).data(), a.data(), 1e-9);
        assert!(orthogonality_defect(&f.q) < 1e-9);
    }
    let s = factor::svd(&a, 0);
    assert!(s.u.data().iter().all(|v| v.is_finite()));
    assert!(s.s.iter().all(|v| v.is_finite()));
}
