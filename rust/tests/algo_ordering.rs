//! Cross-algorithm statistical ordering — the paper's qualitative results,
//! checked end-to-end across datasets and seeds:
//!   optimal ≤ {LELA, SMP-PCA} ≤ SVD(ÃᵀB̃) on cone-like data;
//!   SMP-PCA error decreasing in k (Fig 3b);
//!   rescaled estimator ≥ plain estimator (ablation).

use smppca::algo::{
    lela::LelaConfig, low_rank_product, optimal_rank_r, sketch_svd, smp_pca, spectral_error,
    SmpPcaConfig,
};
use smppca::datasets;
use smppca::rng::Pcg64;
use smppca::sketch::SketchKind;

#[test]
fn table1_ordering_across_seeds() {
    // optimal ≤ lela (small gap), smp close behind — averaged over seeds.
    let mut e_opt = 0.0;
    let mut e_lela = 0.0;
    let mut e_smp = 0.0;
    let trials = 3;
    for s in 0..trials {
        let mut rng = Pcg64::new(1000 + s);
        let (a, b) = datasets::gd_synthetic(150, 60, 60, &mut rng);
        e_opt += spectral_error(&optimal_rank_r(&a, &b, 5), &a, &b);
        e_lela += spectral_error(
            &smppca::algo::lela(&a, &b, &LelaConfig { rank: 5, iters: 8, seed: s, ..Default::default() })
                .unwrap(),
            &a,
            &b,
        );
        let cfg = SmpPcaConfig { rank: 5, sketch_size: 60, iters: 8, seed: s, ..Default::default() };
        e_smp += smp_pca(&a, &b, &cfg).unwrap().spectral_error(&a, &b);
    }
    e_opt /= trials as f64;
    e_lela /= trials as f64;
    e_smp /= trials as f64;
    assert!(e_opt <= e_lela + 0.02, "opt={e_opt} lela={e_lela}");
    assert!(e_opt <= e_smp + 0.02, "opt={e_opt} smp={e_smp}");
    assert!(e_smp < 0.35, "smp absolute error too large: {e_smp}");
}

#[test]
fn smp_beats_sketch_svd_on_cones_multiple_angles() {
    for &theta in &[0.05f64, 0.15] {
        let mut rng = Pcg64::new((theta * 100.0) as u64);
        let (a, b) = datasets::cone_pair(250, 40, theta, &mut rng);
        let cfg = SmpPcaConfig {
            rank: 2,
            sketch_size: 16,
            samples: 1200.0,
            iters: 8,
            seed: 3,
            ..Default::default()
        };
        let e_smp = smp_pca(&a, &b, &cfg).unwrap().spectral_error(&a, &b);
        let e_svd =
            spectral_error(&sketch_svd(&a, &b, 2, 16, SketchKind::Gaussian, 3), &a, &b);
        assert!(e_smp < e_svd, "theta={theta}: smp={e_smp} svd={e_svd}");
    }
}

#[test]
fn error_monotone_in_k_on_average() {
    let mut rng = Pcg64::new(7);
    let (a, b) = datasets::gd_synthetic(200, 50, 50, &mut rng);
    let err_at = |k: usize| -> f64 {
        let mut acc = 0.0;
        for s in 0..3 {
            let cfg = SmpPcaConfig {
                rank: 5,
                sketch_size: k,
                samples: 4000.0,
                iters: 8,
                seed: 100 + s,
                ..Default::default()
            };
            acc += smp_pca(&a, &b, &cfg).unwrap().spectral_error(&a, &b);
        }
        acc / 3.0
    };
    let e8 = err_at(8);
    let e64 = err_at(64);
    let e160 = err_at(160);
    assert!(e64 < e8, "k=8→{e8}, k=64→{e64}");
    assert!(e160 < e8, "k=8→{e8}, k=160→{e160}");
}

#[test]
fn rescaled_beats_plain_estimator_end_to_end() {
    // Ablation: same pipeline, estimator switched — the paper's central
    // claim isolated.
    let mut rng = Pcg64::new(9);
    let (a, b) = datasets::cone_pair(300, 36, 0.1, &mut rng);
    let base = SmpPcaConfig {
        rank: 2,
        sketch_size: 16,
        samples: 1000.0,
        iters: 8,
        seed: 11,
        ..Default::default()
    };
    let mut acc_rescaled = 0.0;
    let mut acc_plain = 0.0;
    for s in 0..3 {
        let mut c1 = base.clone();
        c1.seed = 11 + s;
        let mut c2 = c1.clone();
        c2.plain_estimator = true;
        acc_rescaled += smp_pca(&a, &b, &c1).unwrap().spectral_error(&a, &b);
        acc_plain += smp_pca(&a, &b, &c2).unwrap().spectral_error(&a, &b);
    }
    assert!(
        acc_rescaled < acc_plain,
        "rescaled={acc_rescaled} plain={acc_plain}"
    );
}

#[test]
fn arbr_uninformative_on_orthogonal_topr() {
    let mut rng = Pcg64::new(13);
    let (a, b) = datasets::orthogonal_topr(60, 30, 3, &mut rng);
    let e_arbr = spectral_error(&low_rank_product(&a, &b, 3), &a, &b);
    let e_opt = spectral_error(&optimal_rank_r(&a, &b, 3), &a, &b);
    assert!(e_arbr > 0.9, "e_arbr={e_arbr}");
    assert!(e_opt < 0.3, "e_opt={e_opt}");
}

#[test]
fn sketch_kinds_all_work_end_to_end() {
    let mut rng = Pcg64::new(15);
    let (a, b) = datasets::gd_synthetic(120, 40, 40, &mut rng);
    let opt = spectral_error(&optimal_rank_r(&a, &b, 4), &a, &b);
    for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
        let cfg = SmpPcaConfig {
            rank: 4,
            sketch_size: 64,
            iters: 8,
            seed: 17,
            sketch: kind,
            ..Default::default()
        };
        let e = smp_pca(&a, &b, &cfg).unwrap().spectral_error(&a, &b);
        assert!(e < opt + 0.4, "{kind:?}: err={e} opt={opt}");
    }
}

#[test]
fn remark2_hard_case_degrades_gracefully() {
    // Independent A, B (‖AᵀB‖_F ≪ ‖A‖_F‖B‖_F): the paper predicts SMP-PCA
    // needs far larger k/m — check it degrades but produces finite output,
    // and that the easy (shared-G) case at identical parameters is much
    // better.
    let mut rng = Pcg64::new(19);
    let (ah, bh) = datasets::gd_synthetic_indep(150, 40, 40, &mut rng);
    let (ae, be) = datasets::gd_synthetic(150, 40, 40, &mut rng);
    let cfg = SmpPcaConfig { rank: 4, sketch_size: 60, iters: 6, seed: 21, ..Default::default() };
    let e_hard = smp_pca(&ah, &bh, &cfg).unwrap().spectral_error(&ah, &bh);
    let e_easy = smp_pca(&ae, &be, &cfg).unwrap().spectral_error(&ae, &be);
    assert!(e_hard.is_finite());
    assert!(e_easy < 0.5 * e_hard, "easy={e_easy} hard={e_hard}");
}
