//! PJRT/XLA engine integration tests — gated on `make artifacts` having
//! produced `artifacts/*.hlo.txt`. When artifacts are missing the tests
//! no-op with a notice (CI runs `make artifacts` first; `cargo test` alone
//! must not fail on a fresh checkout).

use smppca::linalg::Mat;
use smppca::rng::Pcg64;
use smppca::runtime::{artifacts_available, NativeEngine, TileEngine, XlaEngine, K_ART, TILE};
use smppca::sketch::{SketchKind, SketchState};

fn artifact_dir() -> std::path::PathBuf {
    // Tests run from the crate root.
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine_or_skip() -> Option<XlaEngine> {
    let dir = artifact_dir();
    if !artifacts_available(&dir) {
        eprintln!("[skip] artifacts missing in {} — run `make artifacts`", dir.display());
        return None;
    }
    Some(XlaEngine::load(&dir).expect("artifacts exist but failed to load/compile"))
}

#[test]
fn xla_engine_loads_and_reports_platform() {
    let Some(engine) = engine_or_skip() else { return };
    let platform = engine.platform().to_lowercase();
    assert!(platform.contains("cpu") || platform.contains("host"), "platform={platform}");
}

#[test]
fn xla_gram_tile_matches_native_engine() {
    let Some(engine) = engine_or_skip() else { return };
    let mut rng = Pcg64::new(1);
    let a = Mat::gaussian(60, 30, &mut rng);
    let b = Mat::gaussian(60, 25, &mut rng);
    let k = 24; // < K_ART: exercises zero-padding of sketch rows
    let sa = SketchState::sketch_matrix(SketchKind::Gaussian, 7, k, &a);
    let sb = SketchState::sketch_matrix(SketchKind::Gaussian, 7, k, &b);
    let is: Vec<usize> = (0..30).step_by(2).collect();
    let js: Vec<usize> = (0..25).step_by(3).collect();
    let native = NativeEngine.rescaled_gram_tile(&sa, &sb, &is, &js);
    let xla = engine.rescaled_gram_tile(&sa, &sb, &is, &js);
    // f32 artifact vs f64 native: relative tolerance scaled by magnitudes.
    let scale = native.max_abs().max(1e-6);
    for i in 0..native.rows() {
        for j in 0..native.cols() {
            let d = (native[(i, j)] - xla[(i, j)]).abs();
            assert!(d < 2e-4 * scale, "({i},{j}): native={} xla={}", native[(i, j)], xla[(i, j)]);
        }
    }
}

#[test]
fn xla_full_tile_boundary() {
    // Exactly TILE columns on both sides — no column padding.
    let Some(engine) = engine_or_skip() else { return };
    let mut rng = Pcg64::new(2);
    let a = Mat::gaussian(80, TILE, &mut rng);
    let b = Mat::gaussian(80, TILE, &mut rng);
    let sa = SketchState::sketch_matrix(SketchKind::Srht, 9, K_ART, &a);
    let sb = SketchState::sketch_matrix(SketchKind::Srht, 9, K_ART, &b);
    let idx: Vec<usize> = (0..TILE).collect();
    let native = NativeEngine.rescaled_gram_tile(&sa, &sb, &idx, &idx);
    let xla = engine.rescaled_gram_tile(&sa, &sb, &idx, &idx);
    let scale = native.max_abs().max(1e-6);
    for i in 0..TILE {
        for j in 0..TILE {
            assert!((native[(i, j)] - xla[(i, j)]).abs() < 3e-4 * scale);
        }
    }
}

#[test]
fn xla_estimate_drives_full_smppca() {
    // End-to-end: SMP-PCA through the XLA estimation engine ≈ native.
    let Some(engine) = engine_or_skip() else { return };
    let mut rng = Pcg64::new(3);
    let (a, b) = smppca::datasets::gd_synthetic(100, 40, 40, &mut rng);
    let cfg = smppca::algo::SmpPcaConfig {
        rank: 4,
        sketch_size: 48,
        iters: 6,
        seed: 5,
        ..Default::default()
    };
    let sa = SketchState::sketch_matrix(cfg.sketch, cfg.seed, cfg.sketch_size, &a);
    let sb = SketchState::sketch_matrix(cfg.sketch, cfg.seed, cfg.sketch_size, &b);
    let native = smppca::algo::finish_from_summaries(&sa, &sb, &cfg).unwrap();
    let xla = smppca::algo::finish_from_summaries_engine(&sa, &sb, &cfg, &engine).unwrap();
    let e_native = smppca::algo::spectral_error(&native.factors, &a, &b);
    let e_xla = smppca::algo::spectral_error(&xla.factors, &a, &b);
    assert!(
        (e_native - e_xla).abs() < 0.05 + 0.3 * e_native,
        "native err {e_native} vs xla err {e_xla}"
    );
}

#[test]
fn xla_sketch_apply_matches_native_gemm() {
    let Some(engine) = engine_or_skip() else { return };
    use smppca::runtime::xla_engine::D_TILE;
    let mut rng = Pcg64::new(4);
    let pi = Mat::gaussian(K_ART, D_TILE, &mut rng);
    let x = Mat::gaussian(D_TILE, TILE, &mut rng);
    let pi32: Vec<f32> = pi.data().iter().map(|&v| v as f32).collect();
    let x32: Vec<f32> = x.data().iter().map(|&v| v as f32).collect();
    let got = engine.sketch_apply_tile(&pi32, &x32).expect("sketch_apply artifact");
    let want = pi.matmul(&x);
    let scale = want.max_abs();
    for i in 0..K_ART {
        for j in 0..TILE {
            let g = got[i * TILE + j] as f64;
            assert!(
                (g - want[(i, j)]).abs() < 5e-4 * scale,
                "({i},{j}): {g} vs {}",
                want[(i, j)]
            );
        }
    }
}

#[test]
fn xla_rejects_oversized_k() {
    let Some(engine) = engine_or_skip() else { return };
    let mut rng = Pcg64::new(5);
    let a = Mat::gaussian(300, 4, &mut rng);
    let sa = SketchState::sketch_matrix(SketchKind::Gaussian, 1, K_ART + 8, &a);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        engine.rescaled_gram_tile(&sa, &sa, &[0], &[0]);
    }));
    assert!(result.is_err(), "k > K_ART must be rejected");
}
