//! Crash-consistency matrix over the SMPC container format (ISSUE 6).
//!
//! Works entirely at the byte level through the public persistence API:
//! a v3 container (magic + version + payload-kind + payload + CRC32
//! trailer) is written once, then systematically damaged — truncated at
//! EVERY byte offset, bit-flipped at every byte — and each damaged file
//! must be *refused with a diagnostic*, never loaded as silently-wrong
//! state. Legacy v1/v2 layouts (no CRC trailer) must keep loading bitwise.

use smppca::linalg::Mat;
use smppca::rng::Pcg64;
use smppca::server::{Snapshot, StreamSession, StreamSpec};
use smppca::sketch::{SketchKind, SketchState};
use smppca::stream::{Entry, EntrySource, ShuffledMatrixSource, StreamMeta};
use std::path::PathBuf;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("smppca_crash_{tag}_{}.bin", std::process::id()))
}

/// A sketch state with real mass (folded entries), checkpointed to bytes.
fn state_bytes(tag: &str) -> (SketchState, Vec<u8>, PathBuf) {
    let mut st = SketchState::new(SketchKind::Gaussian, 7, 12, 18, 9);
    let mut rng = Pcg64::new(3);
    for col in 0..9u32 {
        let entries: Vec<(u32, f64)> =
            (0..18u32).map(|r| (r, rng.next_f64() - 0.5)).collect();
        st.update_col_entries(col as usize, &entries);
    }
    let path = tmp(tag);
    st.checkpoint(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    (st, bytes, path)
}

fn states_bitwise_equal(a: &SketchState, b: &SketchState) -> bool {
    let (fa, fb) = (a.clone().finalize(), b.clone().finalize());
    fa.sketch.data() == fb.sketch.data()
        && fa.col_norms == fb.col_norms
        && fa.fro_sq == fb.fro_sq
}

#[test]
fn truncation_at_every_byte_offset_is_refused() {
    let (_st, bytes, path) = state_bytes("trunc");
    assert!(bytes.len() > 16, "container suspiciously small: {}", bytes.len());
    for cut in 0..bytes.len() {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let err = SketchState::restore(&path)
            .expect_err(&format!("truncation to {cut}/{} bytes must be refused", bytes.len()))
            .to_string();
        // Every refusal must carry a usable diagnostic, not a bare parse
        // failure: either the EOF offset, the CRC verdict, or (for cuts
        // inside the 4-byte magic) the bad-magic story.
        assert!(
            err.contains("byte offset")
                || err.contains("CRC")
                || err.to_lowercase().contains("magic")
                || err.contains("truncated"),
            "cut at {cut}: unhelpful error '{err}'"
        );
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn single_bit_flip_at_every_byte_is_refused() {
    let (_st, bytes, path) = state_bytes("flip");
    for pos in 0..bytes.len() {
        let mut damaged = bytes.clone();
        damaged[pos] ^= 0x10;
        std::fs::write(&path, &damaged).unwrap();
        // Every flip lands in magic, version, kind, payload, or the CRC
        // trailer itself — all are covered: magic/version/kind by explicit
        // checks, payload and trailer by the CRC comparison. A flip may
        // legitimately surface as a shape/plausibility error instead, but
        // it must NEVER load successfully.
        assert!(
            SketchState::restore(&path).is_err(),
            "bit flip at byte {pos} loaded as valid state"
        );
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn appended_garbage_is_refused_with_the_offset() {
    let (_st, bytes, path) = state_bytes("tail");
    let mut extended = bytes.clone();
    extended.extend_from_slice(&[0u8; 7]);
    std::fs::write(&path, &extended).unwrap();
    let err = SketchState::restore(&path).unwrap_err().to_string();
    assert!(err.contains("trailing garbage"), "{err}");
    assert!(
        err.contains(&format!("byte offset {}", bytes.len())),
        "error must name the clean length {}: {err}",
        bytes.len()
    );
    std::fs::remove_file(&path).ok();
}

/// v1/v2 files carry no CRC trailer; damage inside their payload is only
/// caught by shape plausibility. What the matrix pins for them is the
/// positive direction: byte-exact legacy layouts still restore bitwise.
#[test]
fn legacy_v2_rewrite_of_a_v3_file_still_restores_bitwise() {
    let (st, bytes, path) = state_bytes("legacy");
    // A v2 file is the v3 bytes with version=2 and no 4-byte CRC trailer.
    let mut v2 = bytes.clone();
    v2[4..8].copy_from_slice(&2u32.to_le_bytes());
    v2.truncate(bytes.len() - 4);
    std::fs::write(&path, &v2).unwrap();
    let restored = SketchState::restore(&path).unwrap();
    assert!(states_bitwise_equal(&st, &restored), "v2 fallback lost bits");
    // Unknown future versions are refused, naming the supported range.
    let mut v9 = bytes;
    v9[4..8].copy_from_slice(&9u32.to_le_bytes());
    std::fs::write(&path, &v9).unwrap();
    let err = SketchState::restore(&path).unwrap_err().to_string();
    assert!(err.contains("unsupported SMPC format version 9"), "{err}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn serve_snapshot_container_is_covered_by_the_same_matrix() {
    // The ServeSnapshot payload shares the container plumbing; spot-check
    // the matrix holds for it too (truncations stride 7 to keep CI fast —
    // the exhaustive per-byte sweep above already pins the shared reader).
    let spec = StreamSpec {
        meta: StreamMeta { d: 20, n1: 7, n2: 6 },
        algo: smppca::algo::SmpPcaConfig {
            rank: 2,
            sketch_size: 12,
            samples: 200.0,
            iters: 3,
            seed: 5,
            ..Default::default()
        },
        workers: 2,
        channel_capacity: 8,
    };
    let mut rng = Pcg64::new(8);
    let a = Mat::gaussian(20, 7, &mut rng);
    let b = Mat::gaussian(20, 6, &mut rng);
    let mut entries = Vec::new();
    let _ = Box::new(ShuffledMatrixSource { a, b, seed: 4 }).for_each(&mut |e: Entry| {
        entries.push(e);
        std::ops::ControlFlow::Continue(())
    });
    let s = StreamSession::open("crash-snap", spec).unwrap();
    s.ingest(&entries).unwrap();
    let snap = s.refresh().unwrap();
    let path = tmp("snap");
    snap.save(&path).unwrap();
    s.close().unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let reloaded = Snapshot::load(&path).unwrap();
    assert_eq!(reloaded.factors.u.data(), snap.factors.u.data());
    for cut in (0..bytes.len()).step_by(7) {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        assert!(Snapshot::load(&path).is_err(), "snapshot truncated to {cut} bytes loaded");
    }
    for pos in (0..bytes.len()).step_by(7) {
        let mut damaged = bytes.clone();
        damaged[pos] ^= 0x04;
        std::fs::write(&path, &damaged).unwrap();
        assert!(Snapshot::load(&path).is_err(), "snapshot bit flip at {pos} loaded");
    }
    std::fs::remove_file(&path).ok();
}
