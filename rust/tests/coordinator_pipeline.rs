//! Coordinator integration: streaming pipeline vs in-memory reference,
//! failure injection, backpressure under slow workers, file-sourced runs.

use smppca::algo::{smp_pca, SmpPcaConfig};
use smppca::coordinator::{pipeline::lela_pipeline, Pipeline, PipelineConfig};
use smppca::datasets;
use smppca::rng::Pcg64;
use smppca::stream::{Entry, EntrySource, FileSource, ShuffledMatrixSource, StreamMeta};

fn dataset(seed: u64) -> (smppca::linalg::Mat, smppca::linalg::Mat) {
    let mut rng = Pcg64::new(seed);
    datasets::gd_synthetic(64, 24, 20, &mut rng)
}

#[test]
fn pipeline_equals_reference_all_sketch_kinds() {
    use smppca::sketch::SketchKind;
    let (a, b) = dataset(1);
    for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
        let algo = SmpPcaConfig {
            rank: 3,
            sketch_size: 24,
            iters: 5,
            seed: 42,
            sketch: kind,
            ..Default::default()
        };
        let reference = smp_pca(&a, &b, &algo).unwrap();
        let cfg = PipelineConfig { algo, workers: 3, channel_capacity: 64 };
        let out = Pipeline::new(cfg)
            .run(Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 9 }))
            .unwrap();
        smppca::testing::assert_close(
            out.result.factors.u.data(),
            reference.factors.u.data(),
            1e-9,
        );
    }
}

#[test]
fn file_sourced_pipeline_matches_in_memory() {
    let (a, b) = dataset(2);
    let path = std::env::temp_dir().join(format!("smppca_it_{}.csv", std::process::id()));
    FileSource::write(&path, &a, &b).unwrap();
    let algo = SmpPcaConfig { rank: 3, sketch_size: 20, iters: 5, seed: 7, ..Default::default() };
    let reference = smp_pca(&a, &b, &algo).unwrap();
    let cfg = PipelineConfig { algo, workers: 2, channel_capacity: 32 };
    let out = Pipeline::new(cfg)
        .run(Box::new(FileSource::open(&path).unwrap()))
        .unwrap();
    std::fs::remove_file(&path).ok();
    smppca::testing::assert_close(out.result.factors.u.data(), reference.factors.u.data(), 1e-9);
}

#[test]
fn tiny_channel_capacity_still_completes() {
    // Backpressure stress: capacity 1 batch forces constant blocking.
    let (a, b) = dataset(3);
    let algo = SmpPcaConfig { rank: 2, sketch_size: 12, iters: 4, seed: 5, ..Default::default() };
    let cfg = PipelineConfig { algo, workers: 4, channel_capacity: 1 };
    let out = Pipeline::new(cfg)
        .run(Box::new(ShuffledMatrixSource { a, b, seed: 11 }))
        .unwrap();
    assert!(out.result.samples_drawn > 0);
}

#[test]
fn out_of_range_entry_panics_worker_and_is_reported() {
    struct Corrupt;
    impl EntrySource for Corrupt {
        fn meta(&self) -> StreamMeta {
            StreamMeta { d: 4, n1: 3, n2: 3 }
        }
        fn for_each(
            self: Box<Self>,
            f: &mut dyn FnMut(Entry) -> std::ops::ControlFlow<()>,
        ) -> std::ops::ControlFlow<()> {
            f(Entry::a(0, 0, 1.0))?;
            f(Entry::a(0, 99, 1.0))?; // col out of range
            f(Entry::b(0, 0, 1.0))
        }
    }
    let algo = SmpPcaConfig { rank: 1, sketch_size: 4, iters: 2, seed: 1, ..Default::default() };
    let cfg = PipelineConfig { algo, workers: 2, channel_capacity: 8 };
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        Pipeline::new(cfg).run(Box::new(Corrupt))
    }));
    // Either the router/worker panics (propagated) or run returns an Err —
    // corruption must never be silently folded in.
    match result {
        Ok(Ok(_)) => panic!("corrupt entry silently accepted"),
        Ok(Err(_)) | Err(_) => {}
    }
}

#[test]
fn lela_pipeline_matches_in_memory_lela_error() {
    let (a, b) = dataset(4);
    let cfg = PipelineConfig {
        algo: SmpPcaConfig { rank: 3, sketch_size: 16, iters: 6, seed: 13, ..Default::default() },
        workers: 2,
        channel_capacity: 32,
    };
    let (a2, b2) = (a.clone(), b.clone());
    let make = move || -> Box<dyn EntrySource> {
        Box::new(ShuffledMatrixSource { a: a2.clone(), b: b2.clone(), seed: 1 })
    };
    let (lr_stream, _) = lela_pipeline(&make, &cfg).unwrap();
    let lr_mem = smppca::algo::lela(
        &a,
        &b,
        &smppca::algo::lela::LelaConfig { rank: 3, iters: 6, seed: 13, ..Default::default() },
    )
    .unwrap();
    // Identical seeds ⇒ identical sampling ⇒ identical exact entries ⇒
    // identical WAltMin input.
    smppca::testing::assert_close(lr_stream.u.data(), lr_mem.u.data(), 1e-9);
}

#[test]
fn metrics_account_for_all_entries() {
    let (a, b) = dataset(5);
    let nnz = (a.data().iter().filter(|v| **v != 0.0).count()
        + b.data().iter().filter(|v| **v != 0.0).count()) as u64;
    let cfg = PipelineConfig {
        algo: SmpPcaConfig { rank: 2, sketch_size: 8, iters: 3, seed: 3, ..Default::default() },
        workers: 3,
        channel_capacity: 16,
    };
    let out = Pipeline::new(cfg)
        .run(Box::new(ShuffledMatrixSource { a, b, seed: 2 }))
        .unwrap();
    assert_eq!(out.metrics.counter("worker/entries"), nnz);
    assert_eq!(out.metrics.counter("entries_routed"), nnz);
}
