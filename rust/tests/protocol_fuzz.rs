//! Seeded fuzz over the serve line protocol (ISSUE 6) and its TCP framing
//! layer (ISSUE 7).
//!
//! [`ServeProtocol::handle`] is the server's entire untrusted input
//! surface; its contract is "never panic, answer malformed input with an
//! `err ` line". This test hammers that contract deterministically
//! (seeded [`Pcg64`], no wall-clock, no OS randomness) from three angles:
//! raw byte soup, vocabulary soup built from real protocol tokens, and
//! structured mutations of known-good command lines against a live
//! stream. A panic anywhere fails the whole binary; a malformed line
//! answered with anything but `err `/`ok `/a known report shape fails
//! the assertion that names the offending input.
//!
//! The socket fuzz drives the same contract through a live [`NetServer`]:
//! commands split across arbitrary write boundaries, oversized lines, and
//! abrupt disconnects mid-command — every answerable line gets exactly one
//! well-formed response, in order, and the listener survives everything.

use smppca::rng::Pcg64;
use smppca::server::{NetConfig, NetServer, ServeProtocol, PROTOCOL_HELP};

/// Is `resp` a well-formed protocol answer (as opposed to a panic escape
/// hatch or an empty string)? `help` and `streams` have their own shapes;
/// everything else must come back `ok ...`, `err ...`, or a stats/report
/// block.
fn well_formed(resp: &str) -> bool {
    !resp.is_empty()
        && (resp.starts_with("ok")
            || resp.starts_with("err ")
            || resp.starts_with("stats ")
            || resp.starts_with("streams:")
            || resp.starts_with("estimate ")
            || resp.starts_with("block ")
            || resp.starts_with("top ")
            || resp == PROTOCOL_HELP)
}

#[test]
fn raw_byte_soup_never_panics_and_always_errs() {
    let p = ServeProtocol::new();
    let mut rng = Pcg64::new(0xF022);
    for case in 0..4000u32 {
        let len = rng.next_below(120) as usize;
        let line: String = (0..len)
            .map(|_| {
                // Bias toward printable ASCII but keep control chars, high
                // bytes (as replacement-adjacent chars), and separators in
                // the pool — the tokenizer must shrug at all of them.
                match rng.next_below(10) {
                    0 => char::from(rng.next_below(32) as u8), // control
                    1 => char::from_u32(0x80 + rng.next_below(0x2000) as u32).unwrap_or('\u{fffd}'),
                    _ => char::from(0x20 + rng.next_below(0x5f) as u8), // printable
                }
            })
            .collect();
        let resp = p.handle(&line);
        assert!(well_formed(&resp), "case {case}: line {line:?} → {resp:?}");
        // A random line essentially never starts with a real command verb,
        // so almost every one must be refused; verify the refusal shape on
        // the unambiguous ones (empty / unknown first token).
        let first = line.split_whitespace().next().unwrap_or("");
        const VERBS: [&str; 16] = [
            "open", "ingest", "ingest-file", "refresh", "auto-refresh", "stop-refresh",
            "estimate", "block", "top", "stats", "save", "load", "checkpoint", "close",
            "streams", "help",
        ];
        if !VERBS.contains(&first) {
            assert!(resp.starts_with("err "), "case {case}: line {line:?} → {resp:?}");
        }
    }
}

#[test]
fn vocabulary_soup_never_panics() {
    // Token soup assembled from the protocol's own vocabulary: every verb,
    // every open option, record syntax fragments, and adversarial numbers.
    // Stream names are drawn from a pool that is never opened, so even a
    // syntactically perfect line lands on "no such stream" instead of
    // side-effecting the filesystem or spawning workers.
    const TOKENS: [&str; 40] = [
        "open", "ingest", "ingest-file", "refresh", "auto-refresh", "stop-refresh",
        "estimate", "block", "top", "stats", "save", "load", "checkpoint", "close",
        "streams", "help", "ghost", "phantom", "d=", "n1=", "n2=", "k=", "rank=",
        "seed=", "samples=", "iters=", "kind=", "workers=", "cap=", "restore=",
        "A:0:0:1.5", "B:3:2:-0.25", "C:1:1:1", "A:x:y:z", "A:0:0:", ":::",
        "=", "--", "0x7f", "18446744073709551616",
    ];
    const NUMS: [&str; 12] = [
        "0", "1", "7", "64", "-1", "-9223372036854775808", "1e308", "NaN", "inf",
        "99999999999999999999", "3.14", "0.0",
    ];
    let p = ServeProtocol::new();
    let mut rng = Pcg64::new(0xF055);
    for case in 0..4000u32 {
        let ntok = 1 + rng.next_below(8) as usize;
        let mut parts = Vec::with_capacity(ntok);
        for _ in 0..ntok {
            let t = TOKENS[rng.next_below(TOKENS.len() as u64) as usize];
            if t.ends_with('=') {
                parts.push(format!("{t}{}", NUMS[rng.next_below(NUMS.len() as u64) as usize]));
            } else {
                parts.push(t.to_string());
            }
        }
        let line = parts.join(" ");
        let resp = p.handle(&line);
        assert!(well_formed(&resp), "case {case}: line {line:?} → {resp:?}");
    }
    // Nothing in the soup should have opened a stream (a fully-valid
    // `open NAME d= n1= n2=` assembling itself is ~1e-8 per case); the
    // listing must at least keep its shape, and any accident is torn down
    // so no worker pool outlives the test.
    let listing = p.handle("streams");
    assert!(listing.starts_with("streams:"), "{listing}");
    assert!(p.service().close_all().is_empty(), "fuzz left a broken stream behind");
}

#[test]
fn mutated_valid_commands_never_panic_and_never_corrupt_the_stream() {
    let p = ServeProtocol::new();
    let opened = p.handle("open fz d=8 n1=5 n2=4 k=6 rank=2 samples=100 iters=2 seed=3 workers=1");
    assert!(opened.starts_with("ok open fz"), "{opened}");
    // Templates that exercise every read/ingest path against the live
    // stream. File-writing verbs (save/checkpoint) and the background
    // refresher are mutated against a stream name that does not exist, so
    // a mutation that happens to stay valid still has no side effects.
    let templates: [&str; 8] = [
        "ingest fz A:0:0:1.5 B:1:1:-2.0 A:4:2:0.25",
        "estimate fz 0 0",
        "block fz 0 2 0 2",
        "top fz 3",
        "stats fz",
        "refresh fz",
        "save ghost /tmp/never-written",
        "auto-refresh ghost 50",
    ];
    let mut rng = Pcg64::new(0xF0CC);
    for case in 0..3000u32 {
        let base = templates[rng.next_below(templates.len() as u64) as usize];
        let mut line: Vec<char> = base.chars().collect();
        for _ in 0..=rng.next_below(3) {
            match rng.next_below(4) {
                // truncate at a random point
                0 => line.truncate(rng.next_below(line.len() as u64 + 1) as usize),
                // overwrite one char with a random printable
                1 if !line.is_empty() => {
                    let i = rng.next_below(line.len() as u64) as usize;
                    line[i] = char::from(0x20 + rng.next_below(0x5f) as u8);
                }
                // duplicate a random tail
                2 if !line.is_empty() => {
                    let i = rng.next_below(line.len() as u64) as usize;
                    let tail: Vec<char> = line[i..].to_vec();
                    line.extend(tail);
                }
                // insert a separator burst
                _ => {
                    let i = rng.next_below(line.len() as u64 + 1) as usize;
                    for (off, c) in [' ', ':', '=', ' '].into_iter().enumerate() {
                        line.insert(i + off, c);
                    }
                }
            }
        }
        let line: String = line.into_iter().collect();
        let resp = p.handle(&line);
        assert!(well_formed(&resp), "case {case}: line {line:?} → {resp:?}");
    }
    // The stream survived thousands of mutated lines: still listed, still
    // answering stats, still ingesting — the fuzz never wedged or closed it.
    assert_eq!(p.handle("streams"), "streams: fz");
    assert!(p.handle("stats fz").starts_with("stats fz "), "{}", p.handle("stats fz"));
    assert!(p.handle("ingest fz A:0:0:1.0").starts_with("ok"), "stream wedged");
    assert!(p.handle("close fz").starts_with("ok"), "close failed after fuzz");
}

#[test]
fn socket_framing_fuzz_split_writes_oversized_and_disconnects() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::Arc;
    use std::time::Duration;

    const MAX_LINE: usize = 512;
    let proto = Arc::new(ServeProtocol::new());
    let srv = NetServer::start(
        proto.clone(),
        NetConfig { workers: 2, max_line: MAX_LINE, ..Default::default() },
    )
    .unwrap();
    let addr = srv.local_addr();

    // Single-line-response commands only (no `help`/`stats LIVE`), so one
    // answer per answerable line is the exact framing contract. Every
    // stream name is unopened — perfectly valid syntax still has no side
    // effects.
    let mut rng = Pcg64::new(0xF0C4);
    for case in 0..60u32 {
        let nlines = 1 + rng.next_below(7) as usize;
        let mut script: Vec<String> = Vec::new();
        let mut answerable = 0usize;
        for _ in 0..nlines {
            match rng.next_below(6) {
                0 => {
                    // oversized line: refused in order, framing recovers
                    let len = MAX_LINE + 1 + rng.next_below(200) as usize;
                    script.push(format!("zz{}", "a".repeat(len)));
                    answerable += 1;
                }
                1 => script.push(String::new()),          // skipped, no response
                2 => script.push("# comment".to_string()), // skipped, no response
                3 => {
                    // printable byte soup; the zz prefix keeps it from ever
                    // trim()-matching quit/exit/metrics
                    let len = rng.next_below(40) as usize;
                    let soup: String =
                        (0..len).map(|_| char::from(0x20 + rng.next_below(0x5f) as u8)).collect();
                    script.push(format!("zz{soup}"));
                    answerable += 1;
                }
                _ => {
                    const CMDS: [&str; 5] = [
                        "streams",
                        "estimate ghost 0 0",
                        "top ghost",
                        "refresh ghost",
                        "close ghost",
                    ];
                    script.push(CMDS[rng.next_below(CMDS.len() as u64) as usize].to_string());
                    answerable += 1;
                }
            }
        }
        let mut wire: Vec<u8> = Vec::new();
        for l in &script {
            wire.extend_from_slice(l.as_bytes());
            wire.push(b'\n');
        }
        let abrupt = wire.len() > 1 && rng.next_below(4) == 0;
        if abrupt {
            // cut the stream mid-command: everything after the last full
            // newline must die with the connection, silently (responses to
            // the already-complete lines go unread)
            let cut = 1 + rng.next_below(wire.len() as u64 - 1) as usize;
            wire.truncate(cut);
        }
        let c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut r = BufReader::new(c.try_clone().unwrap());
        let mut c = c;
        // split the wire bytes across random write boundaries
        let mut off = 0usize;
        while off < wire.len() {
            let n = 1 + rng.next_below(wire.len() as u64) as usize;
            let end = (off + n).min(wire.len());
            c.write_all(&wire[off..end]).unwrap();
            c.flush().unwrap();
            off = end;
        }
        if abrupt {
            drop((c, r)); // disconnect mid-command; server must shrug
            continue;
        }
        // Exactly one well-formed response per answerable line, in order.
        for i in 0..answerable {
            let mut line = String::new();
            let n = r.read_line(&mut line).unwrap_or_else(|e| {
                panic!("case {case}: read {i}/{answerable} failed: {e} (script {script:?})")
            });
            assert!(n > 0, "case {case}: connection closed after {i}/{answerable} responses");
            let resp = line.trim_end_matches('\n');
            assert!(
                well_formed(resp) || resp.starts_with("err "),
                "case {case}: response {i} malformed: {resp:?} (script {script:?})"
            );
        }
        drop((c, r));
    }

    // The listener survived all of it: a clean session still round-trips.
    let c = TcpStream::connect(addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut r = BufReader::new(c.try_clone().unwrap());
    let mut c = c;
    c.write_all(b"open fzn d=4 n1=3 n2=3 k=6 rank=2 seed=3 samples=40 iters=2 workers=1\n")
        .unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.starts_with("ok open fzn "), "server wedged by fuzz: {line}");
    c.write_all(b"close fzn\n").unwrap();
    line.clear();
    r.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "ok close fzn");
    drop((c, r));
    srv.shutdown();
    assert!(proto.service().close_all().is_empty(), "socket fuzz left a stream behind");
}

#[test]
fn oversized_lines_are_refused_not_crashed() {
    let p = ServeProtocol::new();
    // A single 1 MiB token, and 100k tiny tokens: both ends of the
    // tokenizer's stress envelope.
    let giant_token = "x".repeat(1 << 20);
    assert!(p.handle(&giant_token).starts_with("err "), "giant token accepted");
    let many_tokens = "y ".repeat(100_000);
    assert!(p.handle(&many_tokens).starts_with("err "), "token flood accepted");
    let giant_ingest = format!("ingest nosuch {}", "A:0:0:1 ".repeat(50_000));
    assert!(p.handle(&giant_ingest).starts_with("err "), "flood onto missing stream accepted");
}
