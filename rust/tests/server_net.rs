//! TCP front-end integration (ISSUE 7 acceptance): a multi-client TCP
//! session is **byte-identical** to the stdin protocol on the same script
//! — at 1, 2 and 8 ingest workers, with burst coalescing on — and the
//! shed-load paths (per-burst command budgets, accept-queue overflow)
//! answer with explicit `err shed ...` lines instead of buffering without
//! bound. Quit/disconnect semantics are per-connection: one client ending
//! its session never touches the listener or the other clients.

use smppca::algo::SmpPcaConfig;
use smppca::coordinator::metrics::stage;
use smppca::linalg::Mat;
use smppca::rng::Pcg64;
use smppca::server::{NetConfig, NetServer, ServeProtocol};
use smppca::stream::{Entry, EntrySource, MatrixId, ShuffledMatrixSource};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

const D: usize = 40;
const N1: usize = 14;
const N2: usize = 12;

fn algo() -> SmpPcaConfig {
    SmpPcaConfig {
        rank: 3,
        sketch_size: 24,
        samples: 500.0,
        iters: 5,
        seed: 5,
        ..Default::default()
    }
}

fn stream_entries() -> Vec<Entry> {
    let mut rng = Pcg64::new(42);
    let a = Mat::gaussian(D, N1, &mut rng);
    let b = Mat::gaussian(D, N2, &mut rng);
    let mut out = Vec::new();
    let _ = Box::new(ShuffledMatrixSource { a, b, seed: 77 }).for_each(&mut |e| {
        out.push(e);
        std::ops::ControlFlow::Continue(())
    });
    out
}

/// The session script: setup lines (applied once) + query lines (replayed
/// by every client). Query responses are all single-line, so clients can
/// read exactly one line per command.
fn setup_lines(workers: usize, entries: &[Entry]) -> Vec<String> {
    let a = algo();
    let mut lines = vec![format!(
        "open s d={D} n1={N1} n2={N2} k={} rank={} seed={} samples={} iters={} workers={workers}",
        a.sketch_size, a.rank, a.seed, a.samples, a.iters
    )];
    for chunk in entries.chunks(25) {
        let records: Vec<String> = chunk
            .iter()
            .map(|e| {
                let m = match e.matrix {
                    MatrixId::A => "A",
                    MatrixId::B => "B",
                };
                format!("{m}:{}:{}:{:.17e}", e.row, e.col, e.value)
            })
            .collect();
        lines.push(format!("ingest s {}", records.join(" ")));
    }
    lines.push("refresh s".to_string());
    lines
}

fn query_lines() -> Vec<String> {
    [
        // dense 2×2 run: the TCP path answers this from one block GEMM
        "estimate s 0 0",
        "estimate s 0 1",
        "estimate s 1 0",
        "estimate s 1 1",
        "top s",
        // sparse run (bounding box too big to coalesce into a block)
        "estimate s 2 3",
        "estimate s 13 11",
        // out-of-range + unknown stream keep their per-line error text
        "estimate s 99 0",
        "estimate ghost 0 0",
        "streams",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

fn connect(srv: &NetServer) -> (TcpStream, BufReader<TcpStream>) {
    let c = TcpStream::connect(srv.local_addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let r = BufReader::new(c.try_clone().unwrap());
    (c, r)
}

fn read_lines(r: &mut BufReader<TcpStream>, n: usize) -> Vec<String> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let mut line = String::new();
        assert!(r.read_line(&mut line).unwrap() > 0, "connection closed early");
        out.push(line.trim_end_matches('\n').to_string());
    }
    out
}

#[test]
fn concurrent_tcp_clients_match_stdin_protocol_bitwise_at_1_2_8_workers() {
    let entries = stream_entries();
    let split = entries.len() * 3 / 5;
    let queries = query_lines();
    for workers in [1usize, 2, 8] {
        // Reference: the stdin protocol (per-line `handle`) on one script.
        let reference = ServeProtocol::new();
        for l in setup_lines(workers, &entries[..split]) {
            let resp = reference.handle(&l);
            assert!(resp.starts_with("ok "), "workers={workers}: {resp}");
        }
        let expected: Vec<String> = queries.iter().map(|l| reference.handle(l)).collect();
        reference.service().close_all();

        // The same session over TCP, queries from 3 concurrent clients
        // (bursts coalesced server-side) while a 4th keeps ingesting.
        let proto = Arc::new(ServeProtocol::new());
        let srv = NetServer::start(
            proto.clone(),
            NetConfig { workers: 4, ..Default::default() },
        )
        .unwrap();
        let (mut setup, mut setup_r) = connect(&srv);
        for l in setup_lines(workers, &entries[..split]) {
            setup.write_all(format!("{l}\n").as_bytes()).unwrap();
            let resp = read_lines(&mut setup_r, 1).remove(0);
            assert!(resp.starts_with("ok "), "workers={workers}: {resp}");
        }
        let burst = queries.join("\n") + "\n";
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let burst = burst.clone();
                let addr = srv.local_addr();
                let n = queries.len();
                std::thread::spawn(move || {
                    let c = TcpStream::connect(addr).unwrap();
                    c.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
                    let mut r = BufReader::new(c.try_clone().unwrap());
                    let mut c = c;
                    c.write_all(burst.as_bytes()).unwrap();
                    read_lines(&mut r, n)
                })
            })
            .collect();
        // Concurrent ingest past the queried prefix: published epoch 1 is
        // immutable, so the queries above stay bitwise stable under it.
        for chunk in entries[split..].chunks(25) {
            let records: Vec<String> = chunk
                .iter()
                .map(|e| {
                    let m = match e.matrix {
                        MatrixId::A => "A",
                        MatrixId::B => "B",
                    };
                    format!("{m}:{}:{}:{:.17e}", e.row, e.col, e.value)
                })
                .collect();
            setup.write_all(format!("ingest s {}\n", records.join(" ")).as_bytes()).unwrap();
            let resp = read_lines(&mut setup_r, 1).remove(0);
            assert!(resp.starts_with("ok ingest s "), "{resp}");
        }
        for h in handles {
            let got = h.join().unwrap();
            assert_eq!(got, expected, "workers={workers}: TCP vs stdin protocol");
        }
        // The dense run really went through the block path.
        let stats = proto.handle("stats s");
        assert!(stats.contains("serve/query_blocks"), "no coalesced block GEMM ran: {stats}");
        assert!(stats.contains("serve/query_coalesced"), "{stats}");
        drop((setup, setup_r));
        srv.shutdown();
        for (name, e) in proto.service().close_all() {
            panic!("stream {name} closed with error: {e:#}");
        }
    }
}

#[test]
fn burst_over_budget_sheds_commands_with_explicit_errors() {
    let proto = Arc::new(ServeProtocol::new());
    let srv = NetServer::start(
        proto.clone(),
        NetConfig { workers: 1, queue_budget: 2, ..Default::default() },
    )
    .unwrap();
    let (mut c, mut r) = connect(&srv);
    // 6 pipelined commands in one write: at most 2 per burst are served,
    // the rest come back `err shed ...`. (If the kernel delivers the burst
    // in several reads, each window sheds past its own budget — either
    // way every command is answered and at least one is shed.)
    let burst = "streams\n".repeat(6);
    c.write_all(burst.as_bytes()).unwrap();
    let got = read_lines(&mut r, 6);
    let served = got.iter().filter(|l| *l == "streams: (none)").count();
    let shed = got.iter().filter(|l| l.starts_with("err shed burst over budget")).count();
    assert_eq!(served + shed, 6, "every command answered: {got:?}");
    assert!(served >= 2, "budget-sized prefix must be served: {got:?}");
    assert!(shed >= 1, "over-budget commands must shed: {got:?}");
    assert!(
        srv.metrics().counter(stage::NET_SHED_COMMANDS) >= 1,
        "shed counter must move"
    );
    drop((c, r));
    srv.shutdown();
}

#[test]
fn accept_queue_overflow_sheds_connections() {
    let proto = Arc::new(ServeProtocol::new());
    let srv = NetServer::start(
        proto.clone(),
        NetConfig { workers: 1, backlog: 1, ..Default::default() },
    )
    .unwrap();
    // Pin the only handler to one connection (a served response proves
    // it's bound), then pile on connections until the 1-deep accept queue
    // overflows and one of them reads the shed line.
    let (mut held, mut held_r) = connect(&srv);
    held.write_all(b"streams\n").unwrap();
    assert_eq!(read_lines(&mut held_r, 1), vec!["streams: (none)"]);
    let mut saw_shed = false;
    let mut spares = Vec::new();
    for _ in 0..5 {
        let c = TcpStream::connect(srv.local_addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let mut r = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        match r.read_line(&mut line) {
            Ok(n) if n > 0 && line.starts_with("err shed accept queue full") => {
                saw_shed = true;
                break;
            }
            // queued (no bytes until a handler frees) or closed — keep the
            // socket alive so the queue stays full and try another
            _ => spares.push((c, r)),
        }
    }
    assert!(saw_shed, "accept-queue overflow must shed a connection");
    assert!(srv.metrics().counter(stage::NET_SHED_CONNECTIONS) >= 1);
    drop((held, held_r, spares));
    srv.shutdown();
}

#[test]
fn quit_and_mid_line_disconnect_close_only_their_own_connection() {
    let proto = Arc::new(ServeProtocol::new());
    let srv = NetServer::start(
        proto.clone(),
        NetConfig { workers: 3, ..Default::default() },
    )
    .unwrap();
    let (mut a, mut a_r) = connect(&srv);
    a.write_all(b"open q d=4 n1=3 n2=3 k=6 rank=2 seed=3 samples=40 iters=2 workers=1\n")
        .unwrap();
    assert!(read_lines(&mut a_r, 1)[0].starts_with("ok open q "));
    let (mut b, mut b_r) = connect(&srv);

    // Client A quits (with a pipelined command after the quit, which dies
    // with the connection, like a script ending at `quit`).
    a.write_all(b"streams\nquit\nstreams\n").unwrap();
    assert_eq!(read_lines(&mut a_r, 1), vec!["streams: q"]);
    let mut rest = String::new();
    assert_eq!(a_r.read_to_string(&mut rest).unwrap(), 0, "quit must close A's connection");

    // Client B is untouched — same session state, same server.
    b.write_all(b"streams\n").unwrap();
    assert_eq!(read_lines(&mut b_r, 1), vec!["streams: q"]);

    // Client C disconnects mid-command (no newline): nothing executes, no
    // response, and the server keeps serving everyone else.
    let (mut c, c_r) = connect(&srv);
    c.write_all(b"close q").unwrap(); // dangling partial line
    drop((c, c_r));
    std::thread::sleep(Duration::from_millis(100));
    b.write_all(b"streams\n").unwrap();
    assert_eq!(
        read_lines(&mut b_r, 1),
        vec!["streams: q"],
        "a dangling partial command must not execute"
    );
    drop((a, a_r, b, b_r));
    srv.shutdown();
    proto.service().close_all();
}

#[test]
fn metrics_command_scrapes_counters_and_stream_stats() {
    let proto = Arc::new(ServeProtocol::new());
    let srv = NetServer::start(proto.clone(), NetConfig::default()).unwrap();
    let (mut c, mut c_r) = connect(&srv);
    c.write_all(b"open m d=4 n1=3 n2=3 k=6 rank=2 seed=3 samples=40 iters=2 workers=1\n")
        .unwrap();
    assert!(read_lines(&mut c_r, 1)[0].starts_with("ok open m "));
    c.write_all(b"metrics\n").unwrap();
    // The scrape is one multi-line response; first line is the keyword,
    // and somewhere in it are the net counters and the stream's stats head.
    let mut got = read_lines(&mut c_r, 2).join("\n");
    assert!(got.starts_with("metrics"), "{got}");
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while !(got.contains(stage::NET_CONNECTIONS) && got.contains("stats m ")) {
        assert!(std::time::Instant::now() < deadline, "incomplete scrape: {got}");
        let mut line = String::new();
        if c_r.read_line(&mut line).unwrap_or(0) > 0 {
            got.push('\n');
            got.push_str(line.trim_end_matches('\n'));
        }
    }
    assert!(got.contains(stage::NET_LINES), "{got}");
    drop((c, c_r));
    srv.shutdown();
    proto.service().close_all();
}
