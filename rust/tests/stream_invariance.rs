//! The paper's defining single-pass property: results are invariant to the
//! order in which entries arrive ("the non-zero entries of A and B [may]
//! be presented in any arbitrary order") and to how they are sharded.

use smppca::algo::SmpPcaConfig;
use smppca::coordinator::{Pipeline, PipelineConfig};
use smppca::datasets;
use smppca::rng::Pcg64;
use smppca::runtime::fault;
use smppca::server::{ServeProtocol, StreamSession, StreamSpec};
use smppca::stream::{
    shard_of, BinFileSource, ConcatSource, Entry, EntrySource, InterleavedSource,
    PrefetchBinSource, ReadAheadConfig, ReadMode, ShuffledMatrixSource, StreamMeta,
};
use std::sync::{Mutex, MutexGuard};

fn dataset() -> (smppca::linalg::Mat, smppca::linalg::Mat) {
    let mut rng = Pcg64::new(101);
    datasets::gd_synthetic(48, 18, 16, &mut rng)
}

fn cfg(workers: usize) -> PipelineConfig {
    PipelineConfig {
        algo: SmpPcaConfig { rank: 3, sketch_size: 20, iters: 6, seed: 77, ..Default::default() },
        workers,
        channel_capacity: 128,
    }
}

fn run(src: Box<dyn EntrySource>, workers: usize) -> smppca::algo::LowRank {
    Pipeline::new(cfg(workers)).run(src).unwrap().result.factors
}

#[test]
fn shuffled_orders_agree() {
    let (a, b) = dataset();
    let f1 = run(Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 1 }), 2);
    let f2 = run(Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 999 }), 2);
    smppca::testing::assert_close(f1.u.data(), f2.u.data(), 1e-9);
    smppca::testing::assert_close(f1.v.data(), f2.v.data(), 1e-9);
}

#[test]
fn interleaved_equals_shuffled() {
    let (a, b) = dataset();
    let f1 = run(Box::new(InterleavedSource { a: a.clone(), b: b.clone() }), 3);
    let f2 = run(Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 5 }), 3);
    smppca::testing::assert_close(f1.u.data(), f2.u.data(), 1e-9);
}

#[test]
fn worker_counts_agree() {
    let (a, b) = dataset();
    let f1 = run(Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 3 }), 1);
    let f4 = run(Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 3 }), 4);
    let f8 = run(Box::new(ShuffledMatrixSource { a, b, seed: 3 }), 8);
    smppca::testing::assert_close(f1.u.data(), f4.u.data(), 1e-9);
    smppca::testing::assert_close(f1.u.data(), f8.u.data(), 1e-9);
}

#[test]
fn duplicate_aware_split_entries_accumulate() {
    // A value split across two partial records (v = v1 + v2) must sketch
    // identically to one record — linearity of the sketch, which is what
    // makes log-structured (incremental count) streams work.
    struct SplitSource {
        inner: Vec<Entry>,
        meta: StreamMeta,
    }
    impl EntrySource for SplitSource {
        fn meta(&self) -> StreamMeta {
            self.meta
        }
        fn for_each(
            self: Box<Self>,
            f: &mut dyn FnMut(Entry) -> std::ops::ControlFlow<()>,
        ) -> std::ops::ControlFlow<()> {
            for e in self.inner {
                f(e)?;
            }
            std::ops::ControlFlow::Continue(())
        }
    }
    let (a, b) = dataset();
    let meta = StreamMeta { d: a.rows(), n1: a.cols(), n2: b.cols() };
    let mut whole = Vec::new();
    let mut split = Vec::new();
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            let v = a[(i, j)];
            whole.push(Entry::a(i as u32, j as u32, v));
            split.push(Entry::a(i as u32, j as u32, 0.3 * v));
            split.push(Entry::a(i as u32, j as u32, 0.7 * v));
        }
        for j in 0..b.cols() {
            let v = b[(i, j)];
            whole.push(Entry::b(i as u32, j as u32, v));
            split.push(Entry::b(i as u32, j as u32, 0.5 * v));
            split.push(Entry::b(i as u32, j as u32, 0.5 * v));
        }
    }
    let mut m = smppca::coordinator::Metrics::new();
    let p = Pipeline::new(cfg(2));
    let (sa1, sb1) = p
        .sketch_pass(Box::new(SplitSource { inner: whole, meta }), &mut m)
        .unwrap();
    let (sa2, sb2) = p
        .sketch_pass(Box::new(SplitSource { inner: split, meta }), &mut m)
        .unwrap();
    // Sketches are linear ⇒ identical; norms are NOT (Σv² ≠ (Σv)² per
    // split) — that is a real, documented limitation for split-value
    // streams: norms require one record per final value.
    smppca::testing::assert_close(sa1.sketch.data(), sa2.sketch.data(), 1e-9);
    smppca::testing::assert_close(sb1.sketch.data(), sb2.sketch.data(), 1e-9);
}

#[test]
fn zero_entries_are_noops() {
    let (a, b) = dataset();
    // Append a blanket of explicit zeros; results must not change.
    struct WithZeros {
        a: smppca::linalg::Mat,
        b: smppca::linalg::Mat,
    }
    impl EntrySource for WithZeros {
        fn meta(&self) -> StreamMeta {
            StreamMeta { d: self.a.rows(), n1: self.a.cols(), n2: self.b.cols() }
        }
        fn for_each(
            self: Box<Self>,
            f: &mut dyn FnMut(Entry) -> std::ops::ControlFlow<()>,
        ) -> std::ops::ControlFlow<()> {
            for i in 0..self.a.rows() {
                for j in 0..self.a.cols() {
                    f(Entry::a(i as u32, j as u32, self.a[(i, j)]))?;
                    f(Entry::a(i as u32, j as u32, 0.0))?;
                }
                for j in 0..self.b.cols() {
                    f(Entry::b(i as u32, j as u32, self.b[(i, j)]))?;
                    f(Entry::b(i as u32, j as u32, 0.0))?;
                }
            }
            std::ops::ControlFlow::Continue(())
        }
    }
    let f1 = run(Box::new(WithZeros { a: a.clone(), b: b.clone() }), 2);
    let f2 = run(Box::new(InterleavedSource { a, b }), 2);
    smppca::testing::assert_close(f1.u.data(), f2.u.data(), 1e-9);
}

// --------------------------------------------------- out-of-core backends
//
// ISSUE 10 acceptance: every io backend (buffered / read-ahead prefetch /
// mmap) and every reader×worker combination must produce factors **bitwise
// identical** to the synchronous single-reader drain. The stream layer is
// only allowed to change *when* bytes arrive — never what the snapshot is.

/// Serialize the fault-plan-using leg against the other prefetch-backed
/// tests in this binary: `fault::install` is process-global, so a plan
/// armed for one test must never fire inside a concurrently running
/// reader thread. Same idiom as server_recovery.rs.
static PLAN_LOCK: Mutex<()> = Mutex::new(());

fn io_lock() -> MutexGuard<'static, ()> {
    let guard = PLAN_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    fault::point("test/env-warmup");
    fault::clear();
    guard
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("smppca_inv_{}_{name}", std::process::id()))
}

/// Tiny record-misaligned chunks: every ring hop carries a split record
/// tail, the worst case for the read-ahead reassembly path.
fn stress_cfg() -> ReadAheadConfig {
    ReadAheadConfig { chunk_bytes: 96, ring_chunks: 2 }
}

/// The dataset as one SMPB file (nonzeros of A then B, in row-major order).
fn write_bin(name: &str) -> std::path::PathBuf {
    let (a, b) = dataset();
    let path = tmp(name);
    BinFileSource::write(&path, &a, &b).unwrap();
    path
}

/// The dataset as `nfiles` **column-disjoint** SMPB shards: entry
/// `(matrix, col)` lands in file `shard_of(matrix, col, nfiles)`, the
/// partition under which multi-reader ingest is bitwise deterministic
/// (each column's entries stay in one file ⇒ one reader ⇒ file order).
fn write_shards(name: &str, nfiles: usize) -> Vec<std::path::PathBuf> {
    let (a, b) = dataset();
    let meta = StreamMeta { d: a.rows(), n1: a.cols(), n2: b.cols() };
    let paths: Vec<_> = (0..nfiles).map(|i| tmp(&format!("{name}_{i}"))).collect();
    let mut writers: Vec<_> = paths
        .iter()
        .map(|p| BinFileSource::writer(p, meta).unwrap())
        .collect();
    let _ = Box::new(InterleavedSource { a, b }).for_each(&mut |e| {
        if e.value != 0.0 {
            writers[shard_of(e.matrix, e.col, nfiles)].push(e).unwrap();
        }
        std::ops::ControlFlow::Continue(())
    });
    for w in writers {
        w.finish().unwrap();
    }
    paths
}

/// Round-robin `sources` into `readers` concatenated groups — the same
/// grouping the CLI's `--readers N` applies before `Pipeline::run_multi`.
fn group(mut sources: Vec<Box<dyn EntrySource>>, readers: usize) -> Vec<Box<dyn EntrySource>> {
    let readers = readers.min(sources.len()).max(1);
    if readers == sources.len() {
        return sources;
    }
    let mut groups: Vec<Vec<Box<dyn EntrySource>>> = (0..readers).map(|_| Vec::new()).collect();
    for (i, s) in sources.drain(..).enumerate() {
        groups[i % readers].push(s);
    }
    groups.into_iter().map(|g| Box::new(ConcatSource::new(g)) as Box<dyn EntrySource>).collect()
}

#[test]
fn io_backends_bitwise_match_sync_reader_at_1_2_8_workers() {
    let _g = io_lock();
    let path = write_bin("backends");
    // The oracle: synchronous buffered reads, one worker.
    let base = run(Box::new(BinFileSource::open(&path).unwrap()), 1);
    for workers in [1usize, 2, 8] {
        let f = run(Box::new(BinFileSource::open(&path).unwrap()), workers);
        assert_eq!(f.u.data(), base.u.data(), "buffered workers={workers} (U)");
        assert_eq!(f.v.data(), base.v.data(), "buffered workers={workers} (V)");
        let f = run(Box::new(PrefetchBinSource::open(&path, stress_cfg()).unwrap()), workers);
        assert_eq!(f.u.data(), base.u.data(), "prefetch workers={workers} (U)");
        assert_eq!(f.v.data(), base.v.data(), "prefetch workers={workers} (V)");
        #[cfg(all(feature = "mmap", unix))]
        {
            let f = run(Box::new(smppca::stream::MmapBinSource::open(&path).unwrap()), workers);
            assert_eq!(f.u.data(), base.u.data(), "mmap workers={workers} (U)");
            assert_eq!(f.v.data(), base.v.data(), "mmap workers={workers} (V)");
        }
    }
    std::fs::remove_file(&path).ok();
}

/// The CI io-matrix hook: `SMPPCA_IO` forces a backend for the whole job
/// (buffered / prefetch / mmap legs), and whichever backend the env picks
/// must reproduce the synchronous oracle bitwise. With the env unset this
/// resolves to `Buffered` and degenerates to oracle-vs-oracle — still a
/// valid (if trivial) instance of the contract.
#[test]
fn env_selected_backend_matches_sync_oracle_bitwise() {
    let _g = io_lock();
    let path = write_bin("env_backend");
    let mode = ReadMode::from_env().expect("SMPPCA_IO must parse");
    let base = run(Box::new(BinFileSource::open(&path).unwrap()), 2);
    let f = run(smppca::stream::open_bin_source(&path, mode).unwrap(), 2);
    std::fs::remove_file(&path).ok();
    assert_eq!(f.u.data(), base.u.data(), "io={} (U)", mode.name());
    assert_eq!(f.v.data(), base.v.data(), "io={} (V)", mode.name());
}

#[test]
fn sharded_multi_reader_pipeline_is_bitwise_invariant() {
    let _g = io_lock();
    const NFILES: usize = 4;
    let paths = write_shards("shards", NFILES);
    // The oracle: all shards drained back-to-back by one synchronous reader.
    let sync: Vec<Box<dyn EntrySource>> = paths
        .iter()
        .map(|p| Box::new(BinFileSource::open(p).unwrap()) as Box<dyn EntrySource>)
        .collect();
    let base = Pipeline::new(cfg(1))
        .run(Box::new(ConcatSource::new(sync)))
        .unwrap()
        .result
        .factors;
    for readers in [1usize, 2, 4] {
        for workers in [1usize, 2, 8] {
            let sources: Vec<Box<dyn EntrySource>> = paths
                .iter()
                .map(|p| {
                    Box::new(PrefetchBinSource::open(p, stress_cfg()).unwrap())
                        as Box<dyn EntrySource>
                })
                .collect();
            let f = Pipeline::new(cfg(workers))
                .run_multi(group(sources, readers))
                .unwrap()
                .result
                .factors;
            assert_eq!(f.u.data(), base.u.data(), "readers={readers} workers={workers} (U)");
            assert_eq!(f.v.data(), base.v.data(), "readers={readers} workers={workers} (V)");
        }
    }
    for p in &paths {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn serve_multi_reader_ingest_matches_single_reader_bitwise() {
    let _g = io_lock();
    const NFILES: usize = 4;
    let paths = write_shards("serve_shards", NFILES);
    let (a, b) = dataset();
    let meta = StreamMeta { d: a.rows(), n1: a.cols(), n2: b.cols() };
    let spec = |workers| StreamSpec {
        meta,
        algo: cfg(1).algo,
        workers,
        channel_capacity: 64,
    };
    let open_all = |mode: ReadMode| -> Vec<Box<dyn EntrySource>> {
        paths
            .iter()
            .map(|p| smppca::stream::open_bin_source(p, mode).unwrap())
            .collect()
    };
    // Oracle: one synchronous reader, one worker, odd batch size.
    let base = {
        let s = StreamSession::open("ooc_base", spec(1)).unwrap();
        s.ingest_sources(open_all(ReadMode::Buffered), 1, 7).unwrap();
        let snap = s.refresh().unwrap();
        s.close().unwrap();
        snap
    };
    for (readers, workers, batch) in [(2usize, 2usize, 5usize), (4, 8, 13)] {
        let s = StreamSession::open("ooc_multi", spec(workers)).unwrap();
        let n = s.ingest_sources(open_all(ReadMode::Prefetch), readers, batch).unwrap();
        assert_eq!(n, base.entries_ingested, "readers={readers}: entry counts diverged");
        let snap = s.refresh().unwrap();
        s.close().unwrap();
        assert_eq!(
            snap.factors.u.data(),
            base.factors.u.data(),
            "readers={readers} workers={workers} (U)"
        );
        assert_eq!(
            snap.factors.v.data(),
            base.factors.v.data(),
            "readers={readers} workers={workers} (V)"
        );
    }
    for p in &paths {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn dying_reader_errors_instead_of_hanging_and_session_survives() {
    let _g = io_lock();
    let path = write_bin("fault");
    let (a, b) = dataset();
    let p = ServeProtocol::with_io(1, ReadMode::Prefetch);
    let algo = cfg(1).algo;
    let r = p.handle(&format!(
        "open s d={} n1={} n2={} k={} rank={} seed={} iters={} workers=2",
        a.rows(),
        a.cols(),
        b.cols(),
        algo.sketch_size,
        algo.rank,
        algo.seed,
        algo.iters
    ));
    assert!(r.starts_with("ok open s "), "{r}");
    // Arm a read fault: the prefetch reader dies on its first chunk. The
    // contract is an `err ...` response — not a wedged serve loop.
    fault::install("stream/read/chunk:ioerr@nth=1").unwrap();
    let r = p.handle(&format!("ingest-file s {}", path.display()));
    fault::clear();
    assert!(r.starts_with("err "), "reader fault must surface as err: {r}");
    assert!(r.contains("io error mid-stream"), "unexpected error: {r}");
    // The session is still serviceable: the same file ingests cleanly and
    // the snapshot publishes.
    let r = p.handle(&format!("ingest-file s {} readers=2 io=prefetch", path.display()));
    assert!(r.starts_with("ok ingest-file s "), "{r}");
    assert!(r.contains("files=1 readers=1"), "readers must clamp to file count: {r}");
    let r = p.handle("refresh s");
    assert!(r.starts_with("ok refresh s epoch="), "{r}");
    let r = p.handle("estimate s 0 0");
    assert!(r.starts_with("estimate s "), "{r}");
    assert_eq!(p.handle("close s"), "ok close s");
    std::fs::remove_file(&path).ok();
}
