//! The paper's defining single-pass property: results are invariant to the
//! order in which entries arrive ("the non-zero entries of A and B [may]
//! be presented in any arbitrary order") and to how they are sharded.

use smppca::algo::SmpPcaConfig;
use smppca::coordinator::{Pipeline, PipelineConfig};
use smppca::datasets;
use smppca::rng::Pcg64;
use smppca::stream::{Entry, EntrySource, InterleavedSource, ShuffledMatrixSource, StreamMeta};

fn dataset() -> (smppca::linalg::Mat, smppca::linalg::Mat) {
    let mut rng = Pcg64::new(101);
    datasets::gd_synthetic(48, 18, 16, &mut rng)
}

fn cfg(workers: usize) -> PipelineConfig {
    PipelineConfig {
        algo: SmpPcaConfig { rank: 3, sketch_size: 20, iters: 6, seed: 77, ..Default::default() },
        workers,
        channel_capacity: 128,
    }
}

fn run(src: Box<dyn EntrySource>, workers: usize) -> smppca::algo::LowRank {
    Pipeline::new(cfg(workers)).run(src).unwrap().result.factors
}

#[test]
fn shuffled_orders_agree() {
    let (a, b) = dataset();
    let f1 = run(Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 1 }), 2);
    let f2 = run(Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 999 }), 2);
    smppca::testing::assert_close(f1.u.data(), f2.u.data(), 1e-9);
    smppca::testing::assert_close(f1.v.data(), f2.v.data(), 1e-9);
}

#[test]
fn interleaved_equals_shuffled() {
    let (a, b) = dataset();
    let f1 = run(Box::new(InterleavedSource { a: a.clone(), b: b.clone() }), 3);
    let f2 = run(Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 5 }), 3);
    smppca::testing::assert_close(f1.u.data(), f2.u.data(), 1e-9);
}

#[test]
fn worker_counts_agree() {
    let (a, b) = dataset();
    let f1 = run(Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 3 }), 1);
    let f4 = run(Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: 3 }), 4);
    let f8 = run(Box::new(ShuffledMatrixSource { a, b, seed: 3 }), 8);
    smppca::testing::assert_close(f1.u.data(), f4.u.data(), 1e-9);
    smppca::testing::assert_close(f1.u.data(), f8.u.data(), 1e-9);
}

#[test]
fn duplicate_aware_split_entries_accumulate() {
    // A value split across two partial records (v = v1 + v2) must sketch
    // identically to one record — linearity of the sketch, which is what
    // makes log-structured (incremental count) streams work.
    struct SplitSource {
        inner: Vec<Entry>,
        meta: StreamMeta,
    }
    impl EntrySource for SplitSource {
        fn meta(&self) -> StreamMeta {
            self.meta
        }
        fn for_each(
            self: Box<Self>,
            f: &mut dyn FnMut(Entry) -> std::ops::ControlFlow<()>,
        ) -> std::ops::ControlFlow<()> {
            for e in self.inner {
                f(e)?;
            }
            std::ops::ControlFlow::Continue(())
        }
    }
    let (a, b) = dataset();
    let meta = StreamMeta { d: a.rows(), n1: a.cols(), n2: b.cols() };
    let mut whole = Vec::new();
    let mut split = Vec::new();
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            let v = a[(i, j)];
            whole.push(Entry::a(i as u32, j as u32, v));
            split.push(Entry::a(i as u32, j as u32, 0.3 * v));
            split.push(Entry::a(i as u32, j as u32, 0.7 * v));
        }
        for j in 0..b.cols() {
            let v = b[(i, j)];
            whole.push(Entry::b(i as u32, j as u32, v));
            split.push(Entry::b(i as u32, j as u32, 0.5 * v));
            split.push(Entry::b(i as u32, j as u32, 0.5 * v));
        }
    }
    let mut m = smppca::coordinator::Metrics::new();
    let p = Pipeline::new(cfg(2));
    let (sa1, sb1) = p
        .sketch_pass(Box::new(SplitSource { inner: whole, meta }), &mut m)
        .unwrap();
    let (sa2, sb2) = p
        .sketch_pass(Box::new(SplitSource { inner: split, meta }), &mut m)
        .unwrap();
    // Sketches are linear ⇒ identical; norms are NOT (Σv² ≠ (Σv)² per
    // split) — that is a real, documented limitation for split-value
    // streams: norms require one record per final value.
    smppca::testing::assert_close(sa1.sketch.data(), sa2.sketch.data(), 1e-9);
    smppca::testing::assert_close(sb1.sketch.data(), sb2.sketch.data(), 1e-9);
}

#[test]
fn zero_entries_are_noops() {
    let (a, b) = dataset();
    // Append a blanket of explicit zeros; results must not change.
    struct WithZeros {
        a: smppca::linalg::Mat,
        b: smppca::linalg::Mat,
    }
    impl EntrySource for WithZeros {
        fn meta(&self) -> StreamMeta {
            StreamMeta { d: self.a.rows(), n1: self.a.cols(), n2: self.b.cols() }
        }
        fn for_each(
            self: Box<Self>,
            f: &mut dyn FnMut(Entry) -> std::ops::ControlFlow<()>,
        ) -> std::ops::ControlFlow<()> {
            for i in 0..self.a.rows() {
                for j in 0..self.a.cols() {
                    f(Entry::a(i as u32, j as u32, self.a[(i, j)]))?;
                    f(Entry::a(i as u32, j as u32, 0.0))?;
                }
                for j in 0..self.b.cols() {
                    f(Entry::b(i as u32, j as u32, self.b[(i, j)]))?;
                    f(Entry::b(i as u32, j as u32, 0.0))?;
                }
            }
            std::ops::ControlFlow::Continue(())
        }
    }
    let f1 = run(Box::new(WithZeros { a: a.clone(), b: b.clone() }), 2);
    let f2 = run(Box::new(InterleavedSource { a, b }), 2);
    smppca::testing::assert_close(f1.u.data(), f2.u.data(), 1e-9);
}
