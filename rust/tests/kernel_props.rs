//! Kernel-layer property tests: every SIMD kernel against its scalar
//! oracle, on ragged shapes, plus bitwise run-to-run repeatability and
//! thread-count invariance.
//!
//! Agreement contracts (EXPERIMENTS.md §Perf):
//! * GEMM — SIMD vs scalar ≤ 1e-12 (FMA fuses a rounding, so bits differ
//!   by O(ε)); each kernel individually bitwise-repeatable and bitwise
//!   thread-count-invariant.
//! * FWHT — **bitwise identical** across kernels (pure add/sub over fixed
//!   pairs; lane width and pass blocking only reorder independent pairs).
//! * CountSketch — **bitwise identical** across kernels (buckets and signs
//!   are discrete; the sign applies as `v·±1.0`, a sign-bit flip).
//!
//! Every avx2-specific test skips cleanly (and loudly) when the runner has
//! no AVX2+FMA, so the suite is green on any hardware; the CI kernel-matrix
//! leg re-runs it with `SMPPCA_KERNEL=avx2` on runners that do.

use smppca::linalg::gemm::{self, matmul_naive};
use smppca::linalg::kernels::{self, Kernels};
use smppca::linalg::Mat;
use smppca::rng::Pcg64;
use smppca::sketch::{SketchKind, SketchState, Summary};
use smppca::testing::{assert_close, prop};

fn simd_or_skip(test: &str) -> Option<&'static Kernels> {
    match kernels::avx2() {
        Some(k) => Some(k),
        None => {
            eprintln!("[{test}] skipping: this CPU has no AVX2+FMA");
            None
        }
    }
}

fn rand_mat(r: usize, c: usize, rng: &mut Pcg64) -> Mat {
    Mat::from_fn(r, c, |_, _| rng.next_gaussian())
}

/// The active kernel must be exactly what the env policy resolves to — this
/// is what the CI kernel-matrix legs pin under SMPPCA_KERNEL=scalar/avx2.
#[test]
fn active_kernel_obeys_env_policy() {
    let want = kernels::from_env().expect("SMPPCA_KERNEL must be valid in the test environment");
    assert_eq!(kernels::active().name, want.name);
}

// ----------------------------------------------------------------- GEMM

#[test]
fn gemm_simd_matches_scalar_oracle_on_ragged_shapes() {
    let Some(simd) = simd_or_skip("gemm_simd_matches_scalar_oracle_on_ragged_shapes") else {
        return;
    };
    // Every ragged edge of the blocking: single tiles, partial tiles in m
    // (vs the 8-row AVX2 panel), partial tiles in n, multi-KC k, multi-NC n.
    let shapes = [
        (1usize, 1usize, 1usize),
        (1, 7, 1),
        (5, 3, 2),
        (7, 9, 4),       // m between scalar (4) and avx2 (8) tile heights
        (8, 16, 4),
        (9, 300, 11),    // k spans two KC blocks
        (67, 129, 35),
        (65, 64, 63),
        (3, 300, 520),   // n spans two NC panels
        (130, 40, 70),
    ];
    let mut rng = Pcg64::new(2024);
    for &(m, k, n) in &shapes {
        let a = rand_mat(m, k, &mut rng);
        let b = rand_mat(k, n, &mut rng);
        let naive = matmul_naive(&a, &b);
        let mut c_sc = vec![0.0; m * n];
        let mut c_simd = vec![0.0; m * n];
        gemm::gemm_with(kernels::scalar(), m, n, k, a.data(), k, 1, b.data(), n, 1, &mut c_sc, 1);
        gemm::gemm_with(simd, m, n, k, a.data(), k, 1, b.data(), n, 1, &mut c_simd, 1);
        assert_close(&c_simd, &c_sc, 1e-12);
        assert_close(&c_simd, naive.data(), 1e-12);
    }
}

#[test]
fn gemm_simd_property_ragged_and_strided() {
    let Some(simd) = simd_or_skip("gemm_simd_property_ragged_and_strided") else { return };
    prop(71, 10, |rng| {
        let m = 1 + rng.next_below(90) as usize;
        let k = rng.next_below(70) as usize; // includes k = 0
        let n = 1 + rng.next_below(90) as usize;
        let a = rand_mat(m, k, rng);
        let b = rand_mat(k, n, rng);
        let mut c_sc = vec![0.0; m * n];
        let mut c_simd = vec![0.0; m * n];
        gemm::gemm_with(kernels::scalar(), m, n, k, a.data(), k, 1, b.data(), n, 1, &mut c_sc, 1);
        gemm::gemm_with(simd, m, n, k, a.data(), k, 1, b.data(), n, 1, &mut c_simd, 1);
        assert_close(&c_simd, &c_sc, 1e-12);
        // Aᵀ·B through the strided packing view (packing absorbs the
        // transpose — the panel layout the microkernel sees is identical).
        if k > 0 {
            let mut t_sc = vec![0.0; k * n];
            let mut t_simd = vec![0.0; k * n];
            let at = rand_mat(m, k, rng);
            let bt = rand_mat(m, n, rng);
            gemm::gemm_with(
                kernels::scalar(), k, n, m, at.data(), 1, k, bt.data(), n, 1, &mut t_sc, 1,
            );
            gemm::gemm_with(simd, k, n, m, at.data(), 1, k, bt.data(), n, 1, &mut t_simd, 1);
            assert_close(&t_simd, &t_sc, 1e-12);
        }
    });
}

#[test]
fn gemm_simd_bitwise_repeatable_and_thread_invariant() {
    let Some(simd) = simd_or_skip("gemm_simd_bitwise_repeatable_and_thread_invariant") else {
        return;
    };
    let mut rng = Pcg64::new(77);
    for &(m, k, n) in &[(67usize, 35usize, 129usize), (130, 70, 41)] {
        let a = rand_mat(m, k, &mut rng);
        let b = rand_mat(k, n, &mut rng);
        let mut base = vec![0.0; m * n];
        gemm::gemm_with(simd, m, n, k, a.data(), k, 1, b.data(), n, 1, &mut base, 1);
        // Run-to-run: identical bits on every repeat.
        for _ in 0..3 {
            let mut again = vec![0.0; m * n];
            gemm::gemm_with(simd, m, n, k, a.data(), k, 1, b.data(), n, 1, &mut again, 1);
            assert_eq!(base, again, "SIMD GEMM not repeatable");
        }
        // Thread-count invariance: row sharding never changes an element's
        // k-chain, and the full-padded-tile accumulation makes the chain
        // independent of where a tile sits.
        for threads in [2usize, 3, 4] {
            let mut par = vec![0.0; m * n];
            gemm::gemm_with(simd, m, n, k, a.data(), k, 1, b.data(), n, 1, &mut par, threads);
            assert_eq!(base, par, "SIMD GEMM bits changed at threads={threads}");
        }
    }
}

// ----------------------------------------------------------------- FWHT

#[test]
fn fwht_simd_bitwise_matches_scalar_across_block_boundary() {
    let Some(simd) = simd_or_skip("fwht_simd_bitwise_matches_scalar_across_block_boundary") else {
        return;
    };
    let mut rng = Pcg64::new(31);
    // Sizes straddling every regime: tiny (scalar-h passes only), one
    // vector chunk, exactly the 4096-double cache block, and multi-block
    // sizes that exercise the large-h contiguous-halves sweep.
    for logn in [0usize, 1, 2, 3, 5, 9, 12, 13, 14] {
        let n = 1usize << logn;
        let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mut a = x.clone();
        let mut b = x.clone();
        smppca::linalg::fwht::fwht_inplace_with(kernels::scalar(), &mut a);
        smppca::linalg::fwht::fwht_inplace_with(simd, &mut b);
        assert_eq!(a, b, "FWHT bits diverged at n={n}");
        // Run-to-run repeatability of the SIMD path.
        let mut c = x.clone();
        smppca::linalg::fwht::fwht_inplace_with(simd, &mut c);
        assert_eq!(b, c, "SIMD FWHT not repeatable at n={n}");
    }
}

#[test]
#[should_panic(expected = "power of two")]
fn fwht_dispatch_still_rejects_non_pow2() {
    let mut x = vec![0.0; 12];
    smppca::linalg::fwht::fwht_inplace(&mut x);
}

// ----------------------------------------------------------- CountSketch

#[test]
fn countsketch_kernels_bitwise_match_per_entry_oracle() {
    let Some(simd) = simd_or_skip("countsketch_kernels_bitwise_match_per_entry_oracle") else {
        return;
    };
    prop(83, 12, |rng| {
        // Ragged lengths (not multiples of the 4-lane width) and awkward k,
        // including the k<2 and giant-k scalar-fallback edges.
        let n = 1 + rng.next_below(133) as usize;
        let k = match rng.next_below(5) {
            0 => 1,
            1 => 2 + rng.next_below(30) as usize,
            2 => 1 + rng.next_below(1 << 16) as usize,
            3 => (1 << 20) + rng.next_below(1 << 20) as usize,
            _ => (1usize << 31) + rng.next_below(1 << 10) as usize,
        };
        let seed = rng.next_u64();
        let idx: Vec<u64> = (0..n).map(|_| rng.next_u64() >> 8).collect();
        let vals: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mut got_sc = Vec::new();
        let mut got_simd = Vec::new();
        (kernels::scalar().bucket_signs)(seed, k, &idx, &vals, &mut got_sc);
        (simd.bucket_signs)(seed, k, &idx, &vals, &mut got_simd);
        assert_eq!(got_sc.len(), n);
        assert_eq!(got_simd.len(), n);
        for t in 0..n {
            let (bucket, sign) = smppca::sketch::countsketch::bucket_sign(seed, idx[t], k);
            assert_eq!(got_sc[t].0 as usize, bucket, "scalar bucket k={k} t={t}");
            assert_eq!(got_simd[t].0, got_sc[t].0, "SIMD bucket diverged k={k} t={t}");
            assert_eq!(
                got_simd[t].1.to_bits(),
                (vals[t] * sign).to_bits(),
                "SIMD signed value diverged k={k} t={t}"
            );
        }
    });
}

#[test]
fn countsketch_simd_bitwise_repeatable() {
    let Some(simd) = simd_or_skip("countsketch_simd_bitwise_repeatable") else { return };
    let idx: Vec<u64> = (0..1001).map(|i| i * 37 + 5).collect();
    let vals: Vec<f64> = (0..1001).map(|i| (i as f64).sin()).collect();
    let mut a = Vec::new();
    (simd.bucket_signs)(9, 257, &idx, &vals, &mut a);
    for _ in 0..3 {
        let mut b = Vec::new();
        (simd.bucket_signs)(9, 257, &idx, &vals, &mut b);
        assert_eq!(a, b, "SIMD bucket_signs not repeatable");
    }
}

// ------------------------------------------------- end-to-end ingest paths

fn summaries_for(kind: SketchKind, kern: &'static Kernels) -> (Summary, Summary) {
    let mut rng = Pcg64::new(4242);
    let x = Mat::from_fn(301, 13, |_, _| rng.next_gaussian());
    // Blocked column ingest.
    let mut st = SketchState::new_with_kernel(kind, 17, 24, 301, 13, kern);
    st.ingest_dense(&x);
    // Per-entry streamed ingest (kernel-independent oracle path for
    // Gaussian/CountSketch; SRHT per-entry uses popcount, no FWHT).
    let mut pe = SketchState::new_with_kernel(kind, 17, 24, 301, 13, kern);
    for i in 0..301 {
        for j in 0..13 {
            pe.update_entry(i, j, x[(i, j)]);
        }
    }
    (st.finalize(), pe.finalize())
}

#[test]
fn sketch_ingest_agrees_across_kernels() {
    let Some(simd) = simd_or_skip("sketch_ingest_agrees_across_kernels") else { return };
    for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
        let (blocked_sc, per_entry_sc) = summaries_for(kind, kernels::scalar());
        let (blocked_simd, per_entry_simd) = summaries_for(kind, simd);
        // Per-entry paths never touch the batched kernels → bitwise equal.
        assert_eq!(
            per_entry_sc.sketch.data(),
            per_entry_simd.sketch.data(),
            "{kind:?}: per-entry path must not depend on the kernel"
        );
        match kind {
            // FWHT is bitwise-identical and CountSketch is discrete-exact,
            // so the full blocked ingest must match bit-for-bit.
            SketchKind::Srht | SketchKind::CountSketch => {
                assert_eq!(
                    blocked_sc.sketch.data(),
                    blocked_simd.sketch.data(),
                    "{kind:?}: blocked ingest bits diverged across kernels"
                );
            }
            // Gaussian routes through GEMM (FMA ⇒ O(ε) differences).
            SketchKind::Gaussian => {
                assert_close(blocked_simd.sketch.data(), blocked_sc.sketch.data(), 1e-12);
            }
        }
        assert_eq!(blocked_sc.col_norms, blocked_simd.col_norms, "{kind:?}: norms are exact");
        // And each kernel's blocked path stays consistent with its own
        // per-entry oracle (exact for CountSketch, fp-close for the rest).
        assert_close(blocked_simd.sketch.data(), per_entry_simd.sketch.data(), 1e-10);
    }
}

#[test]
fn srht_apply_bitwise_identical_across_kernels() {
    let Some(simd) = simd_or_skip("srht_apply_bitwise_identical_across_kernels") else { return };
    prop(91, 8, |rng| {
        let d = 3 + rng.next_below(5000) as usize;
        let k = 1 + rng.next_below(d.min(64) as u64) as usize;
        let plan = smppca::sketch::srht::SrhtPlan::new(rng.next_u64(), k, d);
        let col: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let mut pad = vec![0.0; plan.d_pad()];
        let mut out_sc = vec![0.0; k];
        let mut out_simd = vec![0.0; k];
        plan.apply_into_with(kernels::scalar(), &col, &mut pad, &mut out_sc);
        plan.apply_into_with(simd, &col, &mut pad, &mut out_simd);
        assert_eq!(out_sc, out_simd, "SRHT apply bits diverged (d={d} k={k})");
    });
}
