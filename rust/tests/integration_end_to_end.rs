//! Full-system integration: generate realistic workloads → stream from
//! disk through the sharded coordinator → compare every algorithm —
//! the `cargo test` face of the examples/end_to_end driver.

use smppca::algo::{
    lela::LelaConfig, optimal_rank_r, sketch_svd, smp_pca, spectral_error, SmpPcaConfig,
};
use smppca::coordinator::{Pipeline, PipelineConfig};
use smppca::datasets;
use smppca::rng::Pcg64;
use smppca::sketch::SketchKind;
use smppca::stream::{FileSource, ShuffledMatrixSource};

#[test]
fn cooccurrence_workload_end_to_end() {
    // Bag-of-words co-occurrence (the paper's intro example #3): two
    // word-by-paper matrices, AᵀB = co-occurrence counts.
    let mut rng = Pcg64::new(1);
    let (a, b) = datasets::bow_like(400, 60, 50, &mut rng);
    let cfg = SmpPcaConfig { rank: 5, sketch_size: 80, iters: 8, seed: 3, ..Default::default() };
    let out = smp_pca(&a, &b, &cfg).unwrap();
    let err = out.spectral_error(&a, &b);
    let opt = spectral_error(&optimal_rank_r(&a, &b, 5), &a, &b);
    assert!(err < opt + 0.35, "bow: err={err} opt={opt}");
    assert_eq!(out.factors.n1(), 60);
    assert_eq!(out.factors.n2(), 50);
}

#[test]
fn cca_crosscov_workload_end_to_end() {
    // URL-like cross-covariance (intro example #4 / Table 1).
    let mut rng = Pcg64::new(2);
    let (fa, fb) = datasets::url_like(50, 40, 120, &mut rng);
    let (a, b) = (fa.transpose(), fb.transpose()); // URL × feature
    let cfg = SmpPcaConfig { rank: 4, sketch_size: 60, iters: 8, seed: 5, ..Default::default() };
    let out = smp_pca(&a, &b, &cfg).unwrap();
    let err = out.spectral_error(&a, &b);
    assert!(err < 0.8, "url: err={err}");
}

#[test]
fn full_stack_stream_all_baselines_ordering() {
    let mut rng = Pcg64::new(3);
    let (a, b) = datasets::gd_synthetic(128, 48, 48, &mut rng);
    // stream through the pipeline from a disk file
    let path = std::env::temp_dir().join(format!("smppca_e2e_{}.csv", std::process::id()));
    FileSource::write(&path, &a, &b).unwrap();
    let algo = SmpPcaConfig { rank: 5, sketch_size: 64, iters: 8, seed: 7, ..Default::default() };
    let cfg = PipelineConfig { algo: algo.clone(), workers: 2, channel_capacity: 1024 };
    let out = Pipeline::new(cfg)
        .run(Box::new(FileSource::open(&path).unwrap()))
        .unwrap();
    std::fs::remove_file(&path).ok();
    let e_stream = spectral_error(&out.result.factors, &a, &b);
    let e_opt = spectral_error(&optimal_rank_r(&a, &b, 5), &a, &b);
    let e_lela = spectral_error(
        &smppca::algo::lela(&a, &b, &LelaConfig { rank: 5, iters: 8, seed: 7, ..Default::default() })
            .unwrap(),
        &a,
        &b,
    );
    let e_sk = spectral_error(&sketch_svd(&a, &b, 5, 64, SketchKind::Gaussian, 7), &a, &b);
    // paper ordering: optimal best; streaming SMP-PCA sane and competitive.
    assert!(e_opt <= e_stream + 0.02);
    assert!(e_opt <= e_lela + 0.02);
    assert!(e_stream < 0.5, "stream err {e_stream}");
    assert!(e_sk.is_finite());
}

#[test]
fn pca_mode_streaming_matches_reference() {
    // A = B (PCA). The stream carries both A and B entries; summaries must
    // coincide and the result must match the in-memory run.
    let mut rng = Pcg64::new(4);
    let a = datasets::sift_like(50, 32, &mut rng);
    let algo = SmpPcaConfig { rank: 4, sketch_size: 40, iters: 6, seed: 9, ..Default::default() };
    let reference = smp_pca(&a, &a, &algo).unwrap();
    let cfg = PipelineConfig { algo, workers: 2, channel_capacity: 256 };
    let out = Pipeline::new(cfg)
        .run(Box::new(ShuffledMatrixSource { a: a.clone(), b: a.clone(), seed: 13 }))
        .unwrap();
    smppca::testing::assert_close(
        out.result.factors.u.data(),
        reference.factors.u.data(),
        1e-9,
    );
}

#[test]
fn residual_log_shows_convergence_on_realistic_data() {
    let mut rng = Pcg64::new(5);
    let (a, b) = datasets::gd_synthetic(100, 40, 40, &mut rng);
    let cfg = SmpPcaConfig { rank: 5, sketch_size: 60, iters: 10, seed: 11, ..Default::default() };
    let out = smp_pca(&a, &b, &cfg).unwrap();
    let log = &out.residual_log;
    assert_eq!(log.len(), 10);
    assert!(log.last().unwrap() <= &(log[0] + 1e-12), "no progress: {log:?}");
    assert!(log.iter().all(|v| v.is_finite()));
}
