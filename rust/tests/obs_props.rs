//! Observability-layer properties: registry scrape consistency under
//! concurrent recording, histogram merge algebra, bucket-boundary pins,
//! trace ring overflow accounting, and the golden Prometheus exposition.
//!
//! Tests that flip the process-global trace switch serialize on
//! [`trace_lock`] so they never observe each other's spans.

use smppca::runtime::obs::hist::{bucket_index, bucket_upper_ns, HistSnapshot, FINITE};
use smppca::runtime::obs::registry::{prom_name, Registry};
use smppca::runtime::obs::trace;
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

fn trace_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    // A panicking holder must not wedge the other trace tests.
    match LOCK.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

// ---------------------------------------------------------------- registry

/// Scraping while recorders run must never produce a torn histogram:
/// every snapshot is internally consistent (derived count == +Inf
/// cumulative count by construction) and per-bucket counts are monotone
/// non-decreasing across successive snapshots. After the writers join,
/// the final snapshot is exact.
#[test]
fn concurrent_record_while_scrape_is_consistent() {
    let r = Registry::new();
    let h = r.hist("obs_test/lat");
    const WRITERS: usize = 4;
    const PER_WRITER: u64 = 20_000;

    let mut handles = Vec::new();
    for w in 0..WRITERS {
        handles.push(std::thread::spawn(move || {
            // Deterministic per-writer value sweep across many buckets.
            let mut v: u64 = 1 + w as u64;
            for _ in 0..PER_WRITER {
                h.record_ns(v);
                v = v.wrapping_mul(2862933555777941757).wrapping_add(3037000493) % 50_000_000;
            }
        }));
    }

    let mut prev = HistSnapshot::new();
    let mut scrapes = 0u32;
    loop {
        let snap = h.snapshot();
        for (i, (&now, &before)) in snap.counts.iter().zip(prev.counts.iter()).enumerate() {
            assert!(now >= before, "bucket {i} went backwards: {now} < {before}");
        }
        prev = snap;
        scrapes += 1;
        if handles.iter().all(|h| h.is_finished()) {
            break;
        }
        std::thread::yield_now();
    }
    for h in handles {
        h.join().unwrap();
    }
    assert!(scrapes >= 1);
    let fin = h.snapshot();
    assert_eq!(fin.count(), (WRITERS as u64) * PER_WRITER, "no observation lost");
}

#[test]
fn snapshot_merge_is_associative_and_commutative() {
    let mk = |vals: &[u64]| {
        let mut s = HistSnapshot::new();
        for &v in vals {
            s.observe_ns(v);
        }
        s
    };
    let a = mk(&[3, 14, 159, 2_653]);
    let b = mk(&[58, 979, 323_846, 0]);
    let c = mk(&[2_718_281_828, 1, 1, 1]);
    let mut ab_c = a.clone();
    ab_c.merge(&b);
    ab_c.merge(&c);
    let mut bc = b.clone();
    bc.merge(&c);
    let mut a_bc = a.clone();
    a_bc.merge(&bc);
    assert_eq!(ab_c, a_bc, "merge must be associative");
    let mut ba = b.clone();
    ba.merge(&a);
    let mut ab = a.clone();
    ab.merge(&b);
    assert_eq!(ab, ba, "merge must be commutative");
    assert_eq!(ab_c.count(), 12);
}

/// The boundary pins the exposition format depends on: each finite upper
/// bound is the largest value in its own bucket, one more spills over,
/// and the ~√2 geometric spacing holds.
#[test]
fn bucket_boundaries_pin() {
    // Spot-pin the head of the table (1, 2, 3, 5, 7, 11, 15, 22, 31, ...).
    for (i, expect) in [1u64, 2, 3, 5, 7, 11, 15, 22, 31, 45, 63].iter().enumerate() {
        assert_eq!(bucket_upper_ns(i), *expect, "bucket {i}");
    }
    for i in 0..FINITE {
        let u = bucket_upper_ns(i);
        assert_eq!(bucket_index(u), i);
        assert_eq!(bucket_index(u + 1), i + 1);
    }
    // The table reaches past two minutes before the overflow bucket.
    assert!(bucket_upper_ns(FINITE - 1) > 120_000_000_000);
    assert_eq!(bucket_index(u64::MAX), FINITE);
}

/// Golden Prometheus exposition on a private registry: exact framing for
/// a counter, a gauge, and a histogram with known bucket contents.
#[test]
fn prom_exposition_golden() {
    let r = Registry::new();
    r.counter("g/hits").add(7);
    r.gauge("g/level").set(-3);
    let h = r.hist("g/lat");
    h.record_ns(1); // bucket 0, le 1e-9
    h.record_ns(3); // bucket 2, le 3e-9
    h.record_ns(3);
    h.record_ns(u64::MAX); // overflow, only visible in +Inf
    let got = r.prom_text();
    let want = "\
# TYPE smppca_g_hits counter
smppca_g_hits 7
# TYPE smppca_g_lat histogram
smppca_g_lat_bucket{le=\"1e-9\"} 1
smppca_g_lat_bucket{le=\"3e-9\"} 3
smppca_g_lat_bucket{le=\"+Inf\"} 4
smppca_g_lat_sum 18446744073.709551615
smppca_g_lat_count 4
# TYPE smppca_g_level gauge
smppca_g_level -3
";
    // The _sum line depends on float formatting of a huge value; compare
    // the stable lines exactly and the sum line structurally.
    let got_lines: Vec<&str> = got.lines().collect();
    let want_lines: Vec<&str> = want.lines().collect();
    assert_eq!(got_lines.len(), want_lines.len(), "{got}");
    for (g, w) in got_lines.iter().zip(want_lines.iter()) {
        if w.starts_with("smppca_g_lat_sum") {
            assert!(g.starts_with("smppca_g_lat_sum "), "{g}");
        } else {
            assert_eq!(g, w, "\nfull exposition:\n{got}");
        }
    }
    // Exposition lint invariants, same as the CI regex: every non-comment
    // line is `name{labels}? value`.
    for line in got.lines() {
        if line.starts_with('#') {
            assert!(line.starts_with("# TYPE smppca_"), "{line}");
            continue;
        }
        let (series, value) = line.rsplit_once(' ').expect(line);
        assert!(series.starts_with("smppca_"), "{line}");
        assert!(
            value.parse::<f64>().is_ok() || value == "+Inf",
            "unparseable value in '{line}'"
        );
    }
    assert_eq!(prom_name("g/lat"), "smppca_g_lat");
}

/// Labeled histograms keep one family: `# TYPE` emitted once, both
/// streams' series under it, and `le` composed after the stream label.
#[test]
fn prom_labeled_series_share_a_family() {
    let r = Registry::new();
    r.hist_labeled("q/lat", "stream", "a").record_ns(2);
    r.hist_labeled("q/lat", "stream", "b").record_ns(2);
    let got = r.prom_text();
    assert_eq!(got.matches("# TYPE smppca_q_lat histogram").count(), 1, "{got}");
    assert!(got.contains("smppca_q_lat_bucket{stream=\"a\",le=\"2e-9\"} 1"), "{got}");
    assert!(got.contains("smppca_q_lat_bucket{stream=\"b\",le=\"+Inf\"} 1"), "{got}");
    assert!(got.contains("smppca_q_lat_count{stream=\"a\"} 1"), "{got}");
}

// ------------------------------------------------------------------ trace

/// Ring overflow: with a tiny capacity, flooding one thread's ring keeps
/// the newest events, and every drop is accounted in the dropped counter.
#[test]
fn trace_ring_overflow_is_accounted() {
    let _g = trace_lock();
    trace::set_ring_capacity(8);
    trace::set_enabled(true);
    let before = trace::dropped_total();
    const SPANS: u64 = 100;
    // A fresh thread gets a fresh ring with the tiny capacity.
    std::thread::Builder::new()
        .name("obs-flood".into())
        .spawn(|| {
            for _ in 0..SPANS {
                let _s = trace::span("obs_test/flood");
            }
        })
        .unwrap()
        .join()
        .unwrap();
    trace::set_enabled(false);
    trace::set_ring_capacity(trace::DEFAULT_RING_CAPACITY);
    let rows = trace::drain();
    let flood: Vec<_> =
        rows.iter().filter(|r| r.event.name == "obs_test/flood").collect();
    assert_eq!(flood.len(), 8, "ring must retain exactly its capacity");
    assert!(
        flood.iter().all(|r| r.thread_name == "obs-flood"),
        "spans must land on the recording thread's ring"
    );
    let dropped = trace::dropped_total() - before;
    assert_eq!(dropped, SPANS - 8, "every displaced event must be counted");
    // Drained rings are empty.
    assert!(trace::drain().iter().all(|r| r.event.name != "obs_test/flood"));
}

/// Spans recorded while enabled serialize to valid Chrome trace JSON with
/// monotone timestamps (the same properties scripts/check_trace.py
/// asserts on the serve-produced file in CI).
#[test]
fn trace_spans_export_monotone_chrome_json() {
    let _g = trace_lock();
    trace::set_enabled(true);
    {
        let _outer = trace::span("obs_test/outer");
        std::thread::sleep(Duration::from_millis(2));
        let _inner = trace::span("obs_test/inner");
        std::thread::sleep(Duration::from_millis(1));
    }
    trace::set_enabled(false);
    let rows = trace::drain();
    let mine: Vec<_> =
        rows.iter().filter(|r| r.event.name.starts_with("obs_test/")).collect();
    assert_eq!(mine.len(), 2, "both spans recorded");
    // drain() sorts by start timestamp; the outer span started first and
    // lasted longer.
    assert_eq!(mine[0].event.name, "obs_test/outer");
    assert!(mine[0].event.ts_ns <= mine[1].event.ts_ns);
    assert!(mine[0].event.dur_ns > mine[1].event.dur_ns);
    let json = trace::chrome_json(&rows);
    assert!(json.starts_with("{\"traceEvents\":["), "{json}");
    assert!(json.contains("\"ph\":\"M\""), "{json}");
    assert!(json.contains("\"name\":\"obs_test/outer\""), "{json}");
    assert_eq!(json.matches('{').count(), json.matches('}').count());
}

/// The disabled path stays inert even after a full enable/disable cycle
/// (the overhead bench's premise: one relaxed load, nothing recorded).
#[test]
fn disabled_spans_after_cycle_record_nothing() {
    let _g = trace_lock();
    trace::set_enabled(true);
    {
        let _s = trace::span("obs_test/warm");
    }
    trace::set_enabled(false);
    let _ = trace::drain();
    for _ in 0..1000 {
        let _s = trace::span("obs_test/cold");
    }
    assert!(
        trace::drain().iter().all(|r| r.event.name != "obs_test/cold"),
        "disabled span must not record"
    );
}
