//! Property suite for the sketch/stream contracts the parallel ingest
//! subsystem relies on (ISSUE 2):
//!
//! * merge laws — commutative bitwise always; associative + shard-order +
//!   shard-count invariant bitwise under column sharding;
//! * sharded single pass ≡ sequential pass, bitwise, for every `SketchKind`
//!   at 1 / 2 / 8 workers (entry mode and column mode);
//! * SRHT: the O(d log d) FWHT column-batch ingest pins against the O(1)
//!   popcount-parity oracle; `linalg::fwht` pins against a naive Hadamard
//!   multiply (exactly, on integer data);
//! * checkpoint: mid-stream save/resume of the sharded pass is bitwise
//!   equal to an uninterrupted pass.

use smppca::linalg::fwht::fwht_inplace;
use smppca::linalg::Mat;
use smppca::rng::Pcg64;
use smppca::sketch::ingest::{
    ingest_entries, ingest_matrices, ingest_shards, tree_merge, worker_states, IngestConfig,
};
use smppca::sketch::{SketchKind, SketchState, Summary};
use smppca::stream::{
    shard_of, Entry, EntrySource, MatrixId, ShuffledMatrixSource, StreamMeta, VecSource,
};
use smppca::testing::prop;

const KINDS: [SketchKind; 3] =
    [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch];

fn entries_of(a: &Mat, b: &Mat, order_seed: u64) -> (StreamMeta, Vec<Entry>) {
    let meta = StreamMeta { d: a.rows(), n1: a.cols(), n2: b.cols() };
    let mut entries = Vec::new();
    let src: Box<dyn EntrySource> =
        Box::new(ShuffledMatrixSource { a: a.clone(), b: b.clone(), seed: order_seed });
    let _ = src.for_each(&mut |e| {
        entries.push(e);
        std::ops::ControlFlow::Continue(())
    });
    (meta, entries)
}

/// The sequential reference: one state pair, entries applied in stream order.
fn sequential_pass(
    kind: SketchKind,
    seed: u64,
    k: usize,
    meta: StreamMeta,
    entries: &[Entry],
) -> (Summary, Summary) {
    let (sa, sb) = sequential_states(kind, seed, k, meta, entries);
    (sa.finalize(), sb.finalize())
}

fn sequential_states(
    kind: SketchKind,
    seed: u64,
    k: usize,
    meta: StreamMeta,
    entries: &[Entry],
) -> (SketchState, SketchState) {
    let mut sa = SketchState::new(kind, seed, k, meta.d, meta.n1);
    let mut sb = SketchState::new(kind, seed, k, meta.d, meta.n2);
    for e in entries {
        match e.matrix {
            MatrixId::A => sa.update_entry(e.row as usize, e.col as usize, e.value),
            MatrixId::B => sb.update_entry(e.row as usize, e.col as usize, e.value),
        }
    }
    (sa, sb)
}

fn assert_summary_eq(x: &Summary, y: &Summary, ctx: &str) {
    assert_eq!(x.sketch.data(), y.sketch.data(), "{ctx}: sketch bits differ");
    assert_eq!(x.col_norms, y.col_norms, "{ctx}: column norms differ");
    assert_eq!(x.fro_sq, y.fro_sq, "{ctx}: ‖·‖_F² differs");
}

// ------------------------------------------------------------ tentpole law

#[test]
fn sharded_entry_pass_is_bitwise_identical_to_sequential() {
    // The acceptance criterion: Gaussian/SRHT/CountSketch at 1, 2 and 8
    // workers, arbitrary (shuffled) entry order, bitwise equality.
    for kind in KINDS {
        prop(0x51, 2, |rng| {
            let d = 6 + rng.next_below(40) as usize;
            let n1 = 2 + rng.next_below(9) as usize;
            let n2 = 2 + rng.next_below(9) as usize;
            let k = 4 + rng.next_below(12) as usize;
            let a = Mat::gaussian(d, n1, rng);
            let b = Mat::gaussian(d, n2, rng);
            let (meta, entries) = entries_of(&a, &b, rng.next_u64());
            let (ref_a, ref_b) = sequential_pass(kind, 9, k, meta, &entries);
            for workers in [1usize, 2, 8] {
                let run = ingest_entries(
                    Box::new(VecSource { meta, entries: entries.clone() }),
                    kind,
                    9,
                    k,
                    &IngestConfig { workers, channel_capacity: 64, batch: 7 },
                )
                .unwrap();
                let ctx = format!("{kind:?} w={workers}");
                assert_summary_eq(&run.a, &ref_a, &ctx);
                assert_summary_eq(&run.b, &ref_b, &ctx);
            }
        });
    }
}

#[test]
fn sharded_column_pass_is_bitwise_identical_to_sequential_blocked() {
    // Column mode: per-column shards through the batched block kernels vs
    // the sequential blocked pass (sketch_matrix). Also pins the block
    // kernel's block-split invariance end to end.
    for kind in KINDS {
        prop(0x52, 2, |rng| {
            let d = 6 + rng.next_below(200) as usize;
            let n1 = 2 + rng.next_below(20) as usize;
            let n2 = 2 + rng.next_below(20) as usize;
            let k = 4 + rng.next_below(16) as usize;
            let a = Mat::gaussian(d, n1, rng);
            let b = Mat::gaussian(d, n2, rng);
            let ref_a = SketchState::sketch_matrix(kind, 11, k, &a);
            let ref_b = SketchState::sketch_matrix(kind, 11, k, &b);
            for workers in [1usize, 2, 8] {
                let cfg = IngestConfig { workers, ..Default::default() };
                let run = ingest_matrices(&a, &b, kind, 11, k, &cfg).unwrap();
                let ctx = format!("{kind:?} column mode w={workers}");
                assert_summary_eq(&run.a, &ref_a, &ctx);
                assert_summary_eq(&run.b, &ref_b, &ctx);
            }
        });
    }
}

// ------------------------------------------------------------- merge laws

#[test]
fn merge_is_commutative_bitwise_even_for_overlapping_states() {
    // IEEE-754 addition commutes exactly, so a ⊕ b == b ⊕ a bitwise even
    // when both states touched the same columns.
    for kind in KINDS {
        prop(0x53, 3, |rng| {
            let d = 5 + rng.next_below(30) as usize;
            let n = 2 + rng.next_below(8) as usize;
            let x = Mat::gaussian(d, n, rng);
            let mut p = SketchState::new(kind, 4, 8, d, n);
            let mut q = SketchState::new(kind, 4, 8, d, n);
            for i in 0..d {
                for j in 0..n {
                    // overlapping split by entry hash
                    if (i * 7 + j * 13) % 2 == 0 {
                        p.update_entry(i, j, x[(i, j)]);
                    } else {
                        q.update_entry(i, j, x[(i, j)]);
                    }
                }
            }
            let mut pq = p.clone();
            pq.merge(&q);
            let mut qp = q.clone();
            qp.merge(&p);
            assert_eq!(pq.entries_seen(), qp.entries_seen());
            assert_summary_eq(&pq.finalize(), &qp.finalize(), &format!("{kind:?}"));
        });
    }
}

/// Per-shard states exactly as the router would build them.
fn column_sharded_states(
    kind: SketchKind,
    x: &Mat,
    seed: u64,
    k: usize,
    workers: usize,
) -> Vec<SketchState> {
    let mut parts: Vec<SketchState> =
        (0..workers).map(|_| SketchState::new(kind, seed, k, x.rows(), x.cols())).collect();
    for i in 0..x.rows() {
        for j in 0..x.cols() {
            let w = shard_of(MatrixId::A, j as u32, workers);
            parts[w].update_entry(i, j, x[(i, j)]);
        }
    }
    parts
}

#[test]
fn merge_is_associative_bitwise_on_column_shards() {
    for kind in KINDS {
        prop(0x54, 3, |rng| {
            let d = 5 + rng.next_below(30) as usize;
            let n = 3 + rng.next_below(8) as usize;
            let x = Mat::gaussian(d, n, rng);
            let parts = column_sharded_states(kind, &x, 6, 8, 3);
            let (x0, y0, z0) = (parts[0].clone(), parts[1].clone(), parts[2].clone());
            // (x ⊕ y) ⊕ z
            let mut left = x0.clone();
            left.merge(&y0);
            left.merge(&z0);
            // x ⊕ (y ⊕ z)
            let mut yz = y0.clone();
            yz.merge(&z0);
            let mut right = x0.clone();
            right.merge(&yz);
            assert_summary_eq(&left.finalize(), &right.finalize(), &format!("{kind:?}"));
        });
    }
}

#[test]
fn tree_reduce_is_shard_order_and_count_invariant_bitwise() {
    for kind in KINDS {
        prop(0x55, 2, |rng| {
            let d = 5 + rng.next_below(30) as usize;
            let n = 3 + rng.next_below(8) as usize;
            let x = Mat::gaussian(d, n, rng);
            // reference: one shard (= sequential)
            let reference =
                column_sharded_states(kind, &x, 8, 8, 1).pop().unwrap().finalize();
            for workers in [2usize, 5, 8] {
                let parts = column_sharded_states(kind, &x, 8, 8, workers);
                // forward fold
                let mut fwd = parts[0].clone();
                for p in &parts[1..] {
                    fwd.merge(p);
                }
                // shuffled fold
                let mut order: Vec<usize> = (0..workers).collect();
                rng.shuffle(&mut order);
                let mut shuf = parts[order[0]].clone();
                for &w in &order[1..] {
                    shuf.merge(&parts[w]);
                }
                // binary tree (what the coordinator runs)
                let dummy: Vec<(SketchState, SketchState)> =
                    parts.iter().map(|p| (p.clone(), p.clone())).collect();
                let (tree, _) = tree_merge(dummy);
                let ctx = format!("{kind:?} w={workers}");
                assert_summary_eq(&fwd.finalize(), &reference, &ctx);
                assert_summary_eq(&shuf.finalize(), &reference, &ctx);
                assert_summary_eq(&tree.finalize(), &reference, &ctx);
            }
        });
    }
}

// ----------------------------------------------------------- SRHT pinning

#[test]
fn srht_fwht_column_batch_pins_popcount_entry_path() {
    // Same column through (a) the O(1)-per-entry popcount-parity oracle and
    // (b) the O(d log d) FWHT batch kernel. Different reduction orders ⇒
    // fp-close values; identical math ⇒ exact column norms.
    prop(0x56, 8, |rng| {
        let d = 3 + rng.next_below(120) as usize;
        let k = 1 + rng.next_below(d.min(24) as u64) as usize;
        let seed = rng.next_u64();
        let col: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let mut by_entry = SketchState::new(SketchKind::Srht, seed, k, d, 1);
        for (i, &v) in col.iter().enumerate() {
            by_entry.update_entry(i, 0, v);
        }
        let mut by_fwht = SketchState::new(SketchKind::Srht, seed, k, d, 1);
        by_fwht.update_column(0, &col);
        let se = by_entry.finalize();
        let sf = by_fwht.finalize();
        smppca::testing::assert_close(se.sketch.data(), sf.sketch.data(), 1e-11);
        assert_eq!(se.col_norms, sf.col_norms, "norms are order-identical sums");
    });
}

/// Naive Sylvester Hadamard matrix by the block recursion
/// `H_{2n} = [[H_n, H_n], [H_n, −H_n]]` — written without popcount so it is
/// an independent oracle for both `fwht_inplace` and `hadamard_entry_sign`.
fn naive_hadamard(n: usize) -> Vec<Vec<f64>> {
    assert!(n.is_power_of_two());
    let mut h = vec![vec![1.0]];
    let mut m = 1;
    while m < n {
        let mut next = vec![vec![0.0; 2 * m]; 2 * m];
        for (s, row) in h.iter().enumerate() {
            for (i, &v) in row.iter().enumerate() {
                next[s][i] = v;
                next[s][i + m] = v;
                next[s + m][i] = v;
                next[s + m][i + m] = -v;
            }
        }
        h = next;
        m *= 2;
    }
    h
}

#[test]
fn fwht_matches_naive_hadamard_multiply_on_small_pow2() {
    for logn in 0..6 {
        let n = 1usize << logn;
        let h = naive_hadamard(n);
        // Integer-valued input: H·x is integer arithmetic in f64, so the
        // transform must match the naive multiply *exactly*.
        let x: Vec<f64> = (0..n).map(|i| ((i as i64 % 7) - 3) as f64).collect();
        let mut y = x.clone();
        fwht_inplace(&mut y);
        for s in 0..n {
            let direct: f64 = (0..n).map(|i| h[s][i] * x[i]).sum();
            assert_eq!(y[s], direct, "H_{n}[{s}] (integer data must be exact)");
            // and the popcount-parity closed form agrees with the recursion
            for i in 0..n {
                assert_eq!(
                    smppca::linalg::fwht::hadamard_entry_sign(s, i),
                    h[s][i],
                    "closed-form sign at ({s}, {i})"
                );
            }
        }
        // Gaussian input: fp-close.
        let mut rng = Pcg64::new(7 + logn as u64);
        let g: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mut yg = g.clone();
        fwht_inplace(&mut yg);
        for s in 0..n {
            let direct: f64 = (0..n).map(|i| h[s][i] * g[i]).sum();
            assert!((yg[s] - direct).abs() < 1e-10, "row {s}: {} vs {direct}", yg[s]);
        }
    }
}

// ------------------------------------------------------ checkpoint/resume

#[test]
fn sharded_checkpoint_resume_is_bitwise_identical_to_uninterrupted() {
    // Stop the sharded pass mid-stream, checkpoint every worker state,
    // restore, finish the stream, merge: bitwise equal to both the one-shot
    // sharded pass and the sequential reference.
    let tmp = |tag: &str, w: usize, half: &str| {
        std::env::temp_dir().join(format!(
            "smppca_props_ckpt_{}_{tag}_{w}_{half}",
            std::process::id()
        ))
    };
    for kind in KINDS {
        let tag = format!("{kind:?}");
        let mut rng = Pcg64::new(0x57);
        let a = Mat::gaussian(22, 7, &mut rng);
        let b = Mat::gaussian(22, 6, &mut rng);
        let (meta, entries) = entries_of(&a, &b, 31);
        let k = 8;
        let workers = 3;
        let cfg = IngestConfig { workers, channel_capacity: 32, batch: 5 };
        let split = entries.len() / 2;

        // phase 1: first half, then checkpoint every per-worker state
        let states = worker_states(kind, 13, k, meta, workers);
        let (states, _) = ingest_shards(
            Box::new(VecSource { meta, entries: entries[..split].to_vec() }),
            states,
            &cfg,
        )
        .unwrap();
        let mut restored = Vec::new();
        for (w, (sa, sb)) in states.iter().enumerate() {
            let pa = tmp(&tag, w, "a");
            let pb = tmp(&tag, w, "b");
            sa.checkpoint(&pa).unwrap();
            sb.checkpoint(&pb).unwrap();
            let ra = SketchState::restore(&pa).unwrap();
            let rb = SketchState::restore(&pb).unwrap();
            std::fs::remove_file(&pa).ok();
            std::fs::remove_file(&pb).ok();
            restored.push((ra, rb));
        }

        // phase 2: resume from the restored states on the second half
        let (states, _) = ingest_shards(
            Box::new(VecSource { meta, entries: entries[split..].to_vec() }),
            restored,
            &cfg,
        )
        .unwrap();
        let (ma, mb) = tree_merge(states);
        let (res_a, res_b) = (ma.finalize(), mb.finalize());

        // one-shot sharded + sequential references
        let oneshot = ingest_entries(
            Box::new(VecSource { meta, entries: entries.clone() }),
            kind,
            13,
            k,
            &cfg,
        )
        .unwrap();
        let (seq_a, seq_b) = sequential_pass(kind, 13, k, meta, &entries);
        assert_summary_eq(&res_a, &oneshot.a, &format!("{tag} resume vs one-shot A"));
        assert_summary_eq(&res_b, &oneshot.b, &format!("{tag} resume vs one-shot B"));
        assert_summary_eq(&res_a, &seq_a, &format!("{tag} resume vs sequential A"));
        assert_summary_eq(&res_b, &seq_b, &format!("{tag} resume vs sequential B"));
    }
}
