//! Self-healing serving under deterministic fault injection (ISSUE 6).
//!
//! The contract: with a worker-kill fault plan armed, a serve session
//! transparently restarts dead ingest workers from their in-memory
//! checkpoints, replays the journaled batches, and publishes snapshots
//! whose factors are **bitwise identical** to a fault-free run — at 1, 2
//! and 8 workers. When recovery is impossible (a kill-every-batch plan),
//! the session degrades to read-only serving of its last published
//! snapshot instead of wedging or corrupting.
//!
//! Fault plans installed here are process-global (`fault::install`, the
//! same path the `--fault-plan` flag and `SMPPCA_FAULT_PLAN` env use), so
//! every test serializes on one mutex and re-installs its own plan state.

use smppca::algo::SmpPcaConfig;
use smppca::linalg::Mat;
use smppca::rng::Pcg64;
use smppca::runtime::fault;
use smppca::server::{ServeProtocol, StreamSession, StreamSpec};
use smppca::stream::{Entry, EntrySource, ShuffledMatrixSource, StreamMeta};
use std::sync::{Mutex, MutexGuard};

const D: usize = 40;
const N1: usize = 14;
const N2: usize = 12;

static PLAN_LOCK: Mutex<()> = Mutex::new(());

/// Serialize fault-plan state across the binary's parallel test threads.
/// The warmup point forces the one-time `SMPPCA_FAULT_PLAN` env read to
/// happen *before* the test installs its own plan, so the env can never
/// clobber it mid-test.
fn lock() -> MutexGuard<'static, ()> {
    let guard = PLAN_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    fault::point("test/env-warmup");
    fault::clear();
    guard
}

fn algo() -> SmpPcaConfig {
    SmpPcaConfig {
        rank: 3,
        sketch_size: 24,
        samples: 500.0,
        iters: 5,
        seed: 5,
        ..Default::default()
    }
}

fn spec(workers: usize) -> StreamSpec {
    StreamSpec {
        meta: StreamMeta { d: D, n1: N1, n2: N2 },
        algo: algo(),
        workers,
        channel_capacity: 16,
    }
}

fn stream_entries() -> Vec<Entry> {
    let mut rng = Pcg64::new(42);
    let a = Mat::gaussian(D, N1, &mut rng);
    let b = Mat::gaussian(D, N2, &mut rng);
    let mut out = Vec::new();
    let _ = Box::new(ShuffledMatrixSource { a, b, seed: 77 }).for_each(&mut |e| {
        out.push(e);
        std::ops::ControlFlow::Continue(())
    });
    out
}

/// One full serve run: ingest in odd-sized chunks, refresh, return the
/// published snapshot and final stats.
fn run_session(
    name: &str,
    workers: usize,
    entries: &[Entry],
) -> (std::sync::Arc<smppca::server::Snapshot>, smppca::server::StreamStats) {
    let s = StreamSession::open(name, spec(workers)).unwrap();
    for chunk in entries.chunks(9) {
        s.ingest(chunk).unwrap();
    }
    let snap = s.refresh().unwrap();
    let stats = s.stats();
    s.close().unwrap();
    (snap, stats)
}

#[test]
fn worker_kills_recover_bitwise_at_1_2_8_workers() {
    let guard = lock();
    let entries = stream_entries();
    for workers in [1usize, 2, 8] {
        fault::clear();
        let (clean, clean_stats) = run_session("clean", workers, &entries);
        assert_eq!(clean_stats.recoveries, 0, "workers={workers}: clean run must not recover");
        // every=101 keeps the kill cadence above the replay window
        // (checkpoint interval + queue depth), so each episode converges
        // within its restart budget instead of degrading by design.
        fault::install("serve/worker/batch:panic@every=101").unwrap();
        let (healed, stats) = run_session("healed", workers, &entries);
        fault::clear();
        assert!(stats.recoveries >= 1, "workers={workers}: no worker was ever killed");
        assert!(stats.replayed_batches >= 1, "workers={workers}: recovery must replay");
        assert!(!stats.degraded, "workers={workers}: must heal, not degrade");
        assert_eq!(healed.epoch, clean.epoch);
        assert_eq!(healed.entries_ingested, clean.entries_ingested);
        assert_eq!(
            healed.factors.u.data(),
            clean.factors.u.data(),
            "workers={workers}: U diverged after recovery"
        );
        assert_eq!(
            healed.factors.v.data(),
            clean.factors.v.data(),
            "workers={workers}: V diverged after recovery"
        );
        assert_eq!(healed.a_norms, clean.a_norms, "workers={workers}");
        assert_eq!(healed.b_norms, clean.b_norms, "workers={workers}");
    }
    drop(guard);
}

#[test]
fn single_kill_heals_through_a_single_shard_session() {
    let guard = lock();
    let entries = stream_entries();
    fault::clear();
    let (clean, _) = run_session("clean1", 1, &entries);
    fault::install("serve/worker/batch:panic@nth=40").unwrap();
    let (healed, stats) = run_session("healed1", 1, &entries);
    fault::clear();
    assert_eq!(stats.recoveries, 1, "nth trigger fires exactly once: {stats:?}");
    assert_eq!(healed.factors.u.data(), clean.factors.u.data());
    assert_eq!(healed.factors.v.data(), clean.factors.v.data());
    drop(guard);
}

#[test]
fn unrecoverable_shard_degrades_and_last_snapshot_survives() {
    let guard = lock();
    let entries = stream_entries();
    fault::clear();
    let s = StreamSession::open("degrade-e2e", spec(2)).unwrap();
    for chunk in entries.chunks(9) {
        s.ingest(chunk).unwrap();
    }
    let published = s.refresh().unwrap();
    // A kill on every batch outruns any restart budget.
    fault::install("serve/worker/batch:panic@every=1").unwrap();
    let mut failed = None;
    for _ in 0..300 {
        if let Err(e) = s.ingest(&entries[..5]) {
            failed = Some(e.to_string());
            break;
        }
    }
    fault::clear();
    let err = failed.expect("session never degraded under a kill-every-batch plan");
    assert!(err.contains("irrecoverable"), "unexpected degradation error: {err}");
    let stats = s.stats();
    assert!(stats.degraded);
    assert!(stats.recoveries >= 1);
    // Read-only serving survives; mutations are refused with the real story.
    let snap = s.snapshot().expect("published snapshot must outlive degradation");
    assert_eq!(snap.epoch, published.epoch);
    assert_eq!(snap.factors.u.data(), published.factors.u.data());
    assert!(s.ingest(&entries[..1]).unwrap_err().to_string().contains("degraded"));
    assert!(s.refresh().unwrap_err().to_string().contains("degraded"));
    s.close().unwrap();
    drop(guard);
}

#[test]
fn recovery_counters_surface_through_the_line_protocol() {
    let guard = lock();
    let entries = stream_entries();
    fault::install("serve/worker/batch:panic@nth=30").unwrap();
    let p = ServeProtocol::new();
    let a = algo();
    let r = p.handle(&format!(
        "open s d={D} n1={N1} n2={N2} k={} rank={} seed={} samples={} iters={} workers=2",
        a.sketch_size, a.rank, a.seed, a.samples, a.iters
    ));
    assert!(r.starts_with("ok open s "), "{r}");
    for chunk in entries.chunks(9) {
        let records: Vec<String> = chunk
            .iter()
            .map(|e| {
                let m = match e.matrix {
                    smppca::stream::MatrixId::A => "A",
                    smppca::stream::MatrixId::B => "B",
                };
                format!("{m}:{}:{}:{:.17e}", e.row, e.col, e.value)
            })
            .collect();
        let resp = p.handle(&format!("ingest s {}", records.join(" ")));
        assert!(resp.starts_with("ok ingest s "), "{resp}");
    }
    let r = p.handle("refresh s");
    assert!(r.starts_with("ok refresh s epoch=1 "), "{r}");
    fault::clear();
    let r = p.handle("stats s");
    let head = r.lines().next().unwrap();
    assert!(head.contains(" recoveries=1 "), "stats must count the recovery: {head}");
    assert!(head.contains(" replayed="), "{head}");
    assert!(head.contains(" faults_injected="), "{head}");
    assert!(head.contains(" degraded=false"), "{head}");
    assert!(r.contains("serve/recovery"), "stage metrics must show recovery time: {r}");
    assert_eq!(p.handle("streams"), "streams: s", "healthy stream must not be tagged");
    assert_eq!(p.handle("close s"), "ok close s");
    drop(guard);
}

/// CI's checkpoint-ioerr fault-matrix leg sets
/// `SMPPCA_FAULT_PLAN=checkpoint/write:ioerr@nth=1` and runs exactly this
/// test: the injected failure must surface as an error, leave nothing
/// loadable-but-wrong behind, and the immediate retry must produce a
/// checkpoint that resumes bitwise. Without that env the test exercises
/// the same flow by installing the plan itself.
#[test]
fn env_plan_checkpoint_ioerr_is_atomic_and_retryable() {
    let guard = lock();
    let entries = stream_entries();
    fault::clear();
    let dir = std::env::temp_dir().join(format!("smppca_recovery_ckpt_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let s = StreamSession::open("ckpt-ioerr", spec(2)).unwrap();
    for chunk in entries.chunks(9) {
        s.ingest(chunk).unwrap();
    }
    let reference = s.refresh().unwrap();
    // Mirror the CI env plan (re-installing resets its hit counters, so the
    // run is identical whether or not CI exported the env).
    fault::install("checkpoint/write:ioerr@nth=1").unwrap();
    let err = s.checkpoint(&dir).expect_err("first shard write must fail by plan");
    assert!(err.to_string().contains("fault injected"), "{err}");
    assert!(
        !dir.join("gen-000001").join("shard0.a").exists(),
        "failed write must not leave a canonical shard file"
    );
    assert!(
        !dir.join("MANIFEST").exists(),
        "failed first checkpoint must not commit a manifest"
    );
    // Retry with the fault exhausted: full checkpoint lands.
    let shards = s.checkpoint(&dir).unwrap();
    assert_eq!(shards, s.workers());
    s.close().unwrap();
    fault::clear();
    // Resume from the retried checkpoint: bitwise the same published state.
    let states = StreamSession::restore_states(&dir).unwrap();
    let resumed = StreamSession::open_with_states("ckpt-resume", spec(2), states).unwrap();
    let snap = resumed.refresh().unwrap();
    assert_eq!(snap.factors.u.data(), reference.factors.u.data());
    assert_eq!(snap.factors.v.data(), reference.factors.v.data());
    resumed.close().unwrap();
    std::fs::remove_dir_all(&dir).ok();
    drop(guard);
}

#[test]
fn simulated_kill9_mid_checkpoint_leaves_stale_tmp_but_good_file() {
    // A kill -9 between tmp-write and rename leaves a stale `.tmp` sibling
    // and (at worst) the previous canonical file. Simulate with an injected
    // sync failure, then verify the stale tmp is inert: restore reads only
    // canonical names, and a later successful checkpoint replaces the tmp.
    let guard = lock();
    let entries = stream_entries();
    fault::clear();
    let dir = std::env::temp_dir().join(format!("smppca_recovery_kill9_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let s = StreamSession::open("kill9", spec(1)).unwrap();
    s.ingest(&entries[..200]).unwrap();
    s.checkpoint(&dir).unwrap(); // generation 1, good
    let gen1 = std::fs::read(dir.join("gen-000001").join("shard0.a")).unwrap();
    s.ingest(&entries[200..]).unwrap();
    fault::install("checkpoint/sync:ioerr@nth=1").unwrap();
    s.checkpoint(&dir).expect_err("overwrite must fail mid-write");
    fault::clear();
    // The interrupted attempt staged into gen-000002 and never committed:
    // generation 1's bytes are untouched and the manifest still names it.
    assert_eq!(
        std::fs::read(dir.join("gen-000001").join("shard0.a")).unwrap(),
        gen1,
        "failed overwrite must leave the previous checkpoint bitwise intact"
    );
    let states = StreamSession::restore_states(&dir).unwrap();
    assert_eq!(states.len(), 1, "torn staging must not be mistaken for shards");
    // A clean retry supersedes the debris and prunes generation 1.
    s.checkpoint(&dir).unwrap();
    let gen2 = std::fs::read(dir.join("gen-000002").join("shard0.a")).unwrap();
    assert_ne!(gen2, gen1, "gen 2 must land");
    assert!(!dir.join("gen-000001").exists(), "superseded generation must be pruned");
    s.close().unwrap();
    std::fs::remove_dir_all(&dir).ok();
    drop(guard);
}

#[test]
fn interrupted_multi_shard_checkpoint_never_mixes_generations() {
    // The mixed-generation bug: `checkpoint DIR` on a multi-shard session
    // writes several files, each individually atomic — a crash *between*
    // files used to leave shard0 from the new freeze next to shard1 from
    // the old one, every file CRC-valid and the set silently inconsistent.
    // With generation staging + manifest commit, an injected kill between
    // shard writes must leave the previous generation the one that
    // restores, bit for bit.
    let guard = lock();
    let entries = stream_entries();
    fault::clear();
    let dir = std::env::temp_dir().join(format!("smppca_recovery_mixgen_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let s = StreamSession::open("mixgen", spec(2)).unwrap();
    s.ingest(&entries[..300]).unwrap();
    let reference = s.refresh().unwrap();
    assert_eq!(s.checkpoint(&dir).unwrap(), 2); // generation 1: 4 shard files
    // More ingest, then die on the 3rd shard file of the next checkpoint —
    // i.e. between shard 0 (written) and shard 1 (not) of generation 2.
    s.ingest(&entries[300..]).unwrap();
    fault::install("checkpoint/write:ioerr@nth=3").unwrap();
    s.checkpoint(&dir).expect_err("third shard write must fail by plan");
    fault::clear();
    // The torn staging generation really does hold a partial new set…
    assert!(
        dir.join("gen-000002").join("shard0.a").exists(),
        "test premise: the interrupted attempt wrote part of generation 2"
    );
    assert!(!dir.join("gen-000002").join("shard1.b").exists());
    // …but restore sees only committed generation 1: resuming from it and
    // refreshing reproduces the pre-interruption snapshot bitwise. Before
    // the manifest, this restore read gen-2 shard0 + gen-1 shard1.
    let states = StreamSession::restore_states(&dir).unwrap();
    assert_eq!(states.len(), 2);
    let resumed = StreamSession::open_with_states("mixgen-resume", spec(2), states).unwrap();
    let snap = resumed.refresh().unwrap();
    assert_eq!(snap.factors.u.data(), reference.factors.u.data());
    assert_eq!(snap.factors.v.data(), reference.factors.v.data());
    resumed.close().unwrap();
    // A clean retry commits the full-prefix checkpoint as generation 2.
    let want = s.refresh().unwrap();
    s.checkpoint(&dir).unwrap();
    s.close().unwrap();
    let states = StreamSession::restore_states(&dir).unwrap();
    let resumed = StreamSession::open_with_states("mixgen-resume2", spec(2), states).unwrap();
    let snap = resumed.refresh().unwrap();
    assert_eq!(snap.factors.u.data(), want.factors.u.data());
    assert_eq!(snap.factors.v.data(), want.factors.v.data());
    resumed.close().unwrap();
    std::fs::remove_dir_all(&dir).ok();
    drop(guard);
}
