//! Vendored minimal stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this path dependency
//! provides exactly the surface `smppca` uses: [`Error`], [`Result`], the
//! [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and a blanket
//! `From<E: std::error::Error>` so `?` works on io/parse errors. Errors are
//! flattened to their display string at conversion time — good enough for a
//! CLI + test suite; swap in the real crate by deleting this directory and
//! adding `anyhow = "1"` if the registry is ever available.

use std::fmt;

/// String-backed error value. Like `anyhow::Error`, it deliberately does
/// NOT implement `std::error::Error` — that is what keeps the blanket
/// `From<E: std::error::Error>` impl coherent with `From<Error> for Error`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(&e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!("literal {with} captures")`, `anyhow!(displayable_value)`, or
/// `anyhow!("format {}", args)`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// `bail!(...)` — early-return `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, ...)` — `bail!` unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                "condition failed: {}",
                ::std::stringify!($cond)
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/anywhere")?;
        Ok(())
    }

    fn ensure_fail(x: i32) -> Result<i32> {
        ensure!(x > 0, "x must be positive, got {x}");
        Ok(x)
    }

    #[test]
    fn question_mark_converts_io_error() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad {} thing", 7);
        assert_eq!(e.to_string(), "bad 7 thing");
        let v = 3;
        let e = anyhow!("captured {v}");
        assert_eq!(e.to_string(), "captured 3");
        let e = anyhow!(String::from("owned"));
        assert_eq!(e.to_string(), "owned");
    }

    #[test]
    fn ensure_returns_err() {
        assert!(ensure_fail(-1).is_err());
        assert_eq!(ensure_fail(2).unwrap(), 2);
    }
}
