"""AOT lowering: JAX graphs → HLO **text** artifacts for the rust runtime.

HLO text, NOT ``lowered.compile()``/serialized protos: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Shapes are compiled fixed and must match rust/src/runtime/xla_engine.rs:
  K_ART = 128, TILE = 64, D_TILE = 512.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (what
``make artifacts`` runs).
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Compiled artifact shapes — keep in sync with rust/src/runtime/xla_engine.rs.
K_ART = 128
TILE = 64
D_TILE = 512


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_all():
    """Lower every artifact; returns {name: hlo_text}."""
    arts = {}
    arts["sketch_apply"] = to_hlo_text(
        jax.jit(model.sketch_apply).lower(spec(K_ART, D_TILE), spec(D_TILE, TILE))
    )
    arts["rescaled_gram"] = to_hlo_text(
        jax.jit(model.rescaled_gram).lower(
            spec(K_ART, TILE), spec(K_ART, TILE), spec(TILE), spec(TILE)
        )
    )
    arts["model"] = to_hlo_text(
        jax.jit(model.model).lower(
            spec(K_ART, D_TILE),
            spec(D_TILE, TILE),
            spec(D_TILE, TILE),
            spec(TILE),
            spec(TILE),
        )
    )
    return arts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(compat) write model HLO here too")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    for name, text in lower_all().items():
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")
    if args.out:
        pathlib.Path(args.out).write_text(lower_all()["model"])
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
