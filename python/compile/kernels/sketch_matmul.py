"""L1 Pallas kernel: the sketch tile product `Π @ X`.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks the shared
`d` dimension in `d_block` chunks; each step loads a `(k, d_block)` slab of
Π and a `(d_block, n)` slab of X into VMEM and accumulates the `(k, n)`
output tile on the MXU. This is the HBM↔VMEM schedule that replaces the
paper's per-executor Spark partitioning. VMEM at the default AOT shapes
(k=128, d_block=256, n=64): (128·256 + 256·64 + 128·64) f32 ≈ 224 KiB ≪
16 MiB, so the kernel is safely double-bufferable.

`interpret=True` everywhere: the image's PJRT is CPU-only; real-TPU
lowering would emit a Mosaic custom-call the CPU plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(pi_ref, x_ref, o_ref):
    """One grid step: accumulate pi_slab @ x_slab into the output tile."""
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        pi_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("d_block",))
def sketch_matmul(pi, x, *, d_block=256):
    """`Π @ X` via the tiled Pallas kernel.

    pi: (k, d) float32, x: (d, n) float32; d must be divisible by d_block
    (the AOT path pads; tests exercise exact multiples).
    """
    k, d = pi.shape
    d2, n = x.shape
    assert d == d2, f"inner dims mismatch: {d} vs {d2}"
    assert d % d_block == 0, f"d={d} not a multiple of d_block={d_block}"
    grid = (d // d_block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, d_block), lambda i: (0, i)),
            pl.BlockSpec((d_block, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, n), jnp.float32),
        interpret=True,
    )(pi, x)
