"""L1 Pallas kernel: the fused rescaled-JL gram tile (paper Eq. 2).

Computes `D_A · (ÃᵀB̃) · D_B` for one column tile in a single VMEM
residency: the k-deep matmul hits the MXU, the norm reductions and the
diagonal rescale run on the VPU over the same tiles — the gram block never
round-trips to HBM un-rescaled. Zero-padded columns (‖ã‖ = 0) produce
exact zeros, which is what lets the fixed-shape AOT artifact serve smaller
runtime tiles.

VMEM at the AOT shapes (k=128, tile=64): (2·128·64 + 64·64) f32 ≈ 80 KiB.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, na_ref, nb_ref, o_ref):
    a = a_ref[...]
    b = b_ref[...]
    g = jnp.dot(a.T, b, preferred_element_type=jnp.float32)
    sna = jnp.sqrt(jnp.sum(a * a, axis=0))
    snb = jnp.sqrt(jnp.sum(b * b, axis=0))
    da = jnp.where(sna > 0, na_ref[...] / jnp.where(sna > 0, sna, 1.0), 0.0)
    db = jnp.where(snb > 0, nb_ref[...] / jnp.where(snb > 0, snb, 1.0), 0.0)
    o_ref[...] = da[:, None] * g * db[None, :]


@jax.jit
def rescaled_gram(a, b, na, nb):
    """Fused rescaled gram tile.

    a: (k, n1), b: (k, n2) sketched column tiles; na: (n1,), nb: (n2,)
    exact column norms. Returns (n1, n2) float32.
    """
    k, n1 = a.shape
    k2, n2 = b.shape
    assert k == k2, f"sketch depth mismatch: {k} vs {k2}"
    assert na.shape == (n1,) and nb.shape == (n2,)
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((n1, n2), jnp.float32),
        interpret=True,
    )(a, b, na, nb)
