"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
reference. Every Pallas kernel in this package must match its `ref_*`
counterpart to float tolerance on arbitrary shapes (pytest + hypothesis).
"""

import jax.numpy as jnp


def ref_sketch_matmul(pi, x):
    """`Π @ X` — the sketch tile product. Π: (k, d), X: (d, n) → (k, n)."""
    return jnp.dot(pi, x, preferred_element_type=jnp.float32)


def ref_rescaled_gram(a, b, na, nb):
    """The rescaled-JL gram tile (paper Eq. 2), fused form.

    a, b: sketched column tiles (k, n1), (k, n2) — possibly zero-padded
        rows (k up to the compiled K_ART) and zero-padded columns.
    na, nb: exact column norms collected in the single pass, (n1,), (n2,).

    Returns D_A (ÃᵀB̃) D_B with D_A[i] = na[i]/‖a[:, i]‖ (0 when the
    sketched column is zero — the padding guard).
    """
    g = jnp.dot(a.T, b, preferred_element_type=jnp.float32)
    sna = jnp.sqrt(jnp.sum(a * a, axis=0))
    snb = jnp.sqrt(jnp.sum(b * b, axis=0))
    da = jnp.where(sna > 0, na / jnp.where(sna > 0, sna, 1.0), 0.0)
    db = jnp.where(snb > 0, nb / jnp.where(snb > 0, snb, 1.0), 0.0)
    return da[:, None] * g * db[None, :]
