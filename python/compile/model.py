"""L2 — the JAX compute graphs AOT-lowered for the rust runtime.

Three exported graphs (shapes fixed at lowering time, see aot.py):

* ``sketch_apply(pi, x)``       — the Π·X sketch tile (L1 kernel).
* ``rescaled_gram(a, b, na, nb)`` — the fused Eq.-2 gram tile (L1 kernel).
* ``model(pi, xa, xb, na, nb)``  — the composed single-pass summary → gram
  graph (sketch both inputs with the same Π, then the rescaled gram): the
  end-to-end L2 artifact used by the runtime smoke test; proves the two
  kernels lower into one HLO module.

Build-time only: nothing here is imported at runtime — `make artifacts`
lowers these once to HLO text under artifacts/.
"""

import jax

from compile.kernels.rescaled_gram import rescaled_gram
from compile.kernels.sketch_matmul import sketch_matmul


def _d_block_for(d):
    """Largest supported d-chunk that tiles d exactly (256 at the AOT
    shapes; falls back to whole-d for small test shapes)."""
    for cand in (256, 128, 64, 32, 16, 8):
        if d % cand == 0:
            return cand
    return d


def sketch_apply(pi, x):
    """Π·X — L2 alias of the L1 kernel (kept separate so aot.py can lower
    it under its own artifact name and shape)."""
    return sketch_matmul(pi, x, d_block=_d_block_for(x.shape[0]))


def model(pi, xa, xb, na, nb):
    """The composed L2 graph: one-pass summaries → rescaled gram tile.

    pi: (k, d) shared sketch matrix; xa: (d, n1), xb: (d, n2) raw column
    tiles; na, nb exact column norms. Returns the (n1, n2) M̃ tile.
    """
    d_block = _d_block_for(xa.shape[0])
    a = sketch_matmul(pi, xa, d_block=d_block)
    b = sketch_matmul(pi, xb, d_block=d_block)
    return rescaled_gram(a, b, na, nb)
