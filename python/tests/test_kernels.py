"""L1 correctness: Pallas kernels vs the pure-jnp oracle, swept over shapes
and value regimes with hypothesis. This is the CORE kernel-correctness
signal — the rust side trusts the artifacts these kernels lower into.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.rescaled_gram import rescaled_gram
from compile.kernels.sketch_matmul import sketch_matmul

RNG = np.random.default_rng(0)


def rand(*shape, scale=1.0, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


# ---------------------------------------------------------------- sketch ---


class TestSketchMatmul:
    def test_small_exact(self):
        pi = rand(4, 8, seed=1)
        x = rand(8, 3, seed=2)
        got = sketch_matmul(pi, x, d_block=4)
        np.testing.assert_allclose(got, ref.ref_sketch_matmul(pi, x), rtol=1e-5)

    def test_single_block(self):
        pi = rand(16, 32, seed=3)
        x = rand(32, 8, seed=4)
        got = sketch_matmul(pi, x, d_block=32)  # grid of 1
        np.testing.assert_allclose(got, ref.ref_sketch_matmul(pi, x), rtol=1e-5)

    def test_artifact_shapes(self):
        # The exact shapes aot.py compiles.
        pi = rand(128, 512, seed=5)
        x = rand(512, 64, seed=6)
        got = sketch_matmul(pi, x)
        np.testing.assert_allclose(
            got, ref.ref_sketch_matmul(pi, x), rtol=2e-4, atol=2e-4
        )

    def test_zero_input(self):
        pi = jnp.zeros((8, 16), jnp.float32)
        x = rand(16, 4, seed=7)
        assert np.all(np.asarray(sketch_matmul(pi, x, d_block=8)) == 0.0)

    @settings(max_examples=25, deadline=None)
    @given(
        k=st.integers(1, 24),
        blocks=st.integers(1, 6),
        d_block=st.sampled_from([2, 4, 8, 16]),
        n=st.integers(1, 24),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, k, blocks, d_block, n, seed):
        d = blocks * d_block
        pi = rand(k, d, seed=seed)
        x = rand(d, n, seed=seed + 1)
        got = sketch_matmul(pi, x, d_block=d_block)
        np.testing.assert_allclose(
            got, ref.ref_sketch_matmul(pi, x), rtol=1e-4, atol=1e-5
        )

    @settings(max_examples=10, deadline=None)
    @given(
        scale=st.sampled_from([1e-4, 1.0, 1e4]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_value_regimes(self, scale, seed):
        pi = rand(8, 16, scale=scale, seed=seed)
        x = rand(16, 4, scale=scale, seed=seed + 1)
        got = sketch_matmul(pi, x, d_block=8)
        want = ref.ref_sketch_matmul(pi, x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6 * scale * scale)

    def test_rejects_bad_blocking(self):
        pi = rand(4, 10)
        x = rand(10, 3)
        with pytest.raises(AssertionError):
            sketch_matmul(pi, x, d_block=4)  # 10 % 4 != 0


# ---------------------------------------------------------- rescaled gram ---


class TestRescaledGram:
    def _check(self, a, b, na, nb, rtol=1e-5, atol=1e-6):
        got = rescaled_gram(a, b, na, nb)
        want = ref.ref_rescaled_gram(a, b, na, nb)
        np.testing.assert_allclose(got, want, rtol=rtol, atol=atol)

    def test_small_exact(self):
        a = rand(6, 4, seed=10)
        b = rand(6, 5, seed=11)
        na = jnp.abs(rand(4, seed=12)) + 0.1
        nb = jnp.abs(rand(5, seed=13)) + 0.1
        self._check(a, b, na, nb)

    def test_artifact_shapes(self):
        a = rand(128, 64, seed=14)
        b = rand(128, 64, seed=15)
        na = jnp.abs(rand(64, seed=16)) + 0.1
        nb = jnp.abs(rand(64, seed=17)) + 0.1
        self._check(a, b, na, nb, rtol=2e-4, atol=2e-4)

    def test_zero_padded_columns_give_zero(self):
        # The padding guard the AOT artifact relies on: zero sketched
        # columns must produce exactly zero rows/cols regardless of norms.
        a = np.asarray(rand(8, 6, seed=18)).copy()
        a[:, 3:] = 0.0
        b = np.asarray(rand(8, 5, seed=19)).copy()
        b[:, 2:] = 0.0
        na = np.abs(np.asarray(rand(6, seed=20))) + 1.0
        nb = np.abs(np.asarray(rand(5, seed=21))) + 1.0
        out = np.asarray(rescaled_gram(jnp.asarray(a), jnp.asarray(b),
                                       jnp.asarray(na), jnp.asarray(nb)))
        assert np.all(out[3:, :] == 0.0)
        assert np.all(out[:, 2:] == 0.0)
        self._check(jnp.asarray(a), jnp.asarray(b), jnp.asarray(na), jnp.asarray(nb))

    def test_exact_on_collinear(self):
        # cosθ = 1 ⇒ rescaled estimate = na·nb exactly (the paper's
        # motivating property).
        col = np.asarray(rand(16, 1, seed=22))
        a = jnp.asarray(np.tile(col, (1, 3)))
        out = rescaled_gram(a, a, jnp.ones(3), jnp.ones(3))
        np.testing.assert_allclose(out, np.ones((3, 3)), rtol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        k=st.integers(1, 32),
        n1=st.integers(1, 16),
        n2=st.integers(1, 16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, k, n1, n2, seed):
        a = rand(k, n1, seed=seed)
        b = rand(k, n2, seed=seed + 1)
        na = jnp.abs(rand(n1, seed=seed + 2)) + 0.05
        nb = jnp.abs(rand(n2, seed=seed + 3)) + 0.05
        self._check(a, b, na, nb, rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------------- L2 ----


class TestModelGraph:
    def test_composed_graph_matches_refs(self):
        from compile import model

        pi = rand(12, 32, seed=30)
        xa = rand(32, 6, seed=31)
        xb = rand(32, 7, seed=32)
        na = jnp.sqrt(jnp.sum(xa * xa, axis=0))
        nb = jnp.sqrt(jnp.sum(xb * xb, axis=0))
        got = model.model(pi, xa, xb, na, nb)
        a = ref.ref_sketch_matmul(pi, xa)
        b = ref.ref_sketch_matmul(pi, xb)
        want = ref.ref_rescaled_gram(a, b, na, nb)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)

    def test_rescaled_closer_than_plain_on_collinear(self):
        # End-to-end statistical sanity of the L2 graph: for collinear
        # columns the rescaled gram recovers the exact product.
        from compile import model

        col = np.asarray(rand(64, 1, seed=33))
        xa = jnp.asarray(np.hstack([col, 2 * col, -col]))
        pi = rand(8, 64, seed=34) / np.sqrt(8)
        na = jnp.sqrt(jnp.sum(xa * xa, axis=0))
        got = np.asarray(model.model(pi, xa, xa, na, na))
        want = np.asarray(ref.ref_sketch_matmul(xa.T, xa))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
