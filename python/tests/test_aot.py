"""AOT path tests: the lowering pipeline produces parseable HLO text with
the shapes the rust runtime expects, and the lowered modules still compute
what the kernels compute (via jax round-trip execution).
"""

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def test_lower_all_produces_hlo_text():
    arts = aot.lower_all()
    assert set(arts) == {"sketch_apply", "rescaled_gram", "model"}
    for name, text in arts.items():
        assert "HloModule" in text, f"{name} missing HloModule header"
        assert len(text) > 200


def test_artifact_shapes_in_hlo():
    arts = aot.lower_all()
    # rescaled_gram signature: f32[128,64], f32[128,64], f32[64], f32[64]
    assert "f32[128,64]" in arts["rescaled_gram"]
    assert "f32[64,64]" in arts["rescaled_gram"]
    # sketch_apply: f32[128,512] x f32[512,64] -> f32[128,64]
    assert "f32[128,512]" in arts["sketch_apply"]
    assert "f32[512,64]" in arts["sketch_apply"]


def test_lowered_model_executes_correctly():
    """Compile the lowered StableHLO back through jax and compare numerics —
    proves the artifact pipeline didn't change semantics."""
    k, d, n = aot.K_ART, aot.D_TILE, aot.TILE
    rng = np.random.default_rng(7)
    pi = jnp.asarray(rng.standard_normal((k, d), dtype=np.float32) / np.sqrt(k))
    xa = jnp.asarray(rng.standard_normal((d, n), dtype=np.float32))
    xb = jnp.asarray(rng.standard_normal((d, n), dtype=np.float32))
    na = jnp.sqrt(jnp.sum(xa * xa, axis=0))
    nb = jnp.sqrt(jnp.sum(xb * xb, axis=0))
    compiled = jax.jit(model.model).lower(pi, xa, xb, na, nb).compile()
    got = np.asarray(compiled(pi, xa, xb, na, nb))
    a = ref.ref_sketch_matmul(pi, xa)
    b = ref.ref_sketch_matmul(pi, xb)
    want = np.asarray(ref.ref_rescaled_gram(a, b, na, nb))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_zero_pad_contract():
    """The padding contract the rust engine uses: extra zero sketch rows and
    zero-norm pad columns change nothing."""
    rng = np.random.default_rng(8)
    k, n = 16, 8
    a = rng.standard_normal((k, n), dtype=np.float32)
    b = rng.standard_normal((k, n), dtype=np.float32)
    na = np.abs(rng.standard_normal(n, dtype=np.float32)) + 0.1
    nb = np.abs(rng.standard_normal(n, dtype=np.float32)) + 0.1
    base = np.asarray(model.rescaled_gram(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(na), jnp.asarray(nb)))
    # pad rows to 32 and columns to 12 with zeros
    a_pad = np.zeros((32, 12), np.float32)
    b_pad = np.zeros((32, 12), np.float32)
    a_pad[:k, :n] = a
    b_pad[:k, :n] = b
    na_pad = np.zeros(12, np.float32)
    nb_pad = np.zeros(12, np.float32)
    na_pad[:n] = na
    nb_pad[:n] = nb
    out = np.asarray(model.rescaled_gram(
        jnp.asarray(a_pad), jnp.asarray(b_pad),
        jnp.asarray(na_pad), jnp.asarray(nb_pad)))
    np.testing.assert_allclose(out[:n, :n], base, rtol=1e-5, atol=1e-6)
    assert np.all(out[n:, :] == 0.0)
    assert np.all(out[:, n:] == 0.0)
